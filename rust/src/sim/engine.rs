//! Discrete-event simulation core.
//!
//! A monotonic event queue plus a small resource model, shared by every
//! timing layer of the simulator:
//!
//! * **[`Sharing::Fifo`] resources** serve one task at a time in arrival
//!   order — D2D links executing collective steps, the on-package
//!   execution slot of the mini-batch pipeline.
//! * **[`Sharing::Fair`] resources** are fluid bandwidth servers: all
//!   active transfers progress simultaneously at `bandwidth / k` — the
//!   DRAM channel pool ([`crate::memory::dram::DramModel::resource`]).
//!
//! Workloads are expressed as a task DAG: each [`task`](EventEngine::task)
//! names the resource it occupies, the service it needs ([`Service::Busy`]
//! duration or [`Service::Transfer`] bytes) and the tasks that must finish
//! first. [`run`](EventEngine::run) executes the DAG and returns per-task
//! start/finish times plus per-resource busy time.
//!
//! Determinism: ties are broken by event sequence number and task creation
//! order, so the same graph always produces bit-identical results. The
//! builder is immutable under `run`, so one graph can be re-run (and the
//! engine can be cloned and extended for scenario sweeps).
//!
//! # Hot-path layout
//!
//! Sweeps time the same plan shapes millions of times, so the execution
//! state is split from the graph and made reusable:
//!
//! * [`EventEngine`] is a *slab* builder: resource names share one string
//!   arena, task dependency lists share one `Vec<TaskId>` arena — adding a
//!   task never allocates a per-task `Vec`. [`reset`](EventEngine::reset)
//!   clears the graph while keeping every buffer's capacity.
//! * [`Kernel`] owns all per-run state (indegrees, CSR children, fair
//!   flows, the event queue) and is reusable across runs and across
//!   engines: [`Kernel::execute`] re-initializes in place.
//! * [`EngineArena`] bundles one engine and one kernel — the unit of reuse
//!   threaded through [`crate::sim::system`], [`crate::sim::cluster`] and
//!   [`crate::sched::pipeline`].
//!
//! The event queue is a calendar (time-wheel) queue: [`WHEEL_SLOTS`]
//! buckets of width `makespan_hint / 64`, each bucket a small binary heap,
//! with an overflow heap for events outside the wheel's window. Pops
//! compare the current bucket's top against the overflow top, so the pop
//! order is *exactly* the global `(time, seq)` order of a single binary
//! heap for any bucket width — the width only affects how much ordering
//! work the heaps do. `Kernel::set_heap_only` routes every event through
//! the overflow heap, reproducing the legacy single-heap behaviour
//! bit-for-bit; the parity tests lean on this.
//!
//! On congestion-free graphs the engine reproduces the closed-form models
//! exactly: a single flow on a fair resource finishes at `bytes/bandwidth`,
//! serialized steps on FIFO links sum, and the two-stage mini-batch
//! pipeline lands on `max(A,B) + min(A,B)/n` (property-tested below and in
//! [`crate::sched::pipeline`]).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::{Bytes, Seconds};

/// Task handle returned by [`EventEngine::task`].
pub type TaskId = usize;
/// Resource handle returned by [`EventEngine::resource`].
pub type ResourceId = usize;

/// Number of buckets in the calendar queue.
const WHEEL_SLOTS: usize = 256;
/// Bucket width is `makespan_hint / WHEEL_SPAN_DIV`, so the wheel's window
/// covers `WHEEL_SLOTS / WHEEL_SPAN_DIV` = 4× the hinted makespan before
/// events spill to the overflow heap.
const WHEEL_SPAN_DIV: f64 = 64.0;

/// What a task asks of its resource.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Service {
    /// Occupy the resource for a fixed duration (FIFO resources; on a fair
    /// resource this is converted to `duration × bandwidth` service bytes).
    Busy(Seconds),
    /// Move this many bytes through the resource's bandwidth.
    Transfer(Bytes),
}

/// How a resource serves concurrent tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sharing {
    /// One task at a time, in arrival order (exclusive server).
    Fifo,
    /// Fluid fair sharing: `k` active transfers each progress at
    /// `bandwidth / k`.
    Fair,
}

#[derive(Debug, Clone, Copy)]
struct ResourceSpec {
    /// Range of the resource's name in the engine's shared name arena.
    name_start: usize,
    name_end: usize,
    bandwidth: f64,
    sharing: Sharing,
}

#[derive(Debug, Clone, Copy)]
struct TaskSpec {
    resource: ResourceId,
    service: Service,
    /// Range of the task's dependency list in the engine's shared arena.
    deps_start: usize,
    deps_end: usize,
}

/// Task-graph builder. Per-run execution state lives in [`Kernel`].
#[derive(Debug, Clone, Default)]
pub struct EventEngine {
    resources: Vec<ResourceSpec>,
    tasks: Vec<TaskSpec>,
    /// Name arena: every resource name, concatenated.
    names: String,
    /// Dependency arena: every task's dependency list, concatenated.
    deps: Vec<TaskId>,
}

/// Result of one simulation run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Completion time of the last task (0 for an empty graph).
    pub makespan: Seconds,
    /// Per-task service start time (for FIFO tasks: when the resource
    /// actually began serving, not queue arrival).
    pub start: Vec<Seconds>,
    /// Per-task completion time.
    pub finish: Vec<Seconds>,
    /// Per-resource total busy time (FIFO: sum of service durations;
    /// fair: time with at least one active flow).
    pub busy: Vec<Seconds>,
    /// Number of events processed (diagnostic).
    pub events: usize,
}

impl EventEngine {
    pub fn new() -> EventEngine {
        EventEngine::default()
    }

    /// Clear the task graph, keeping every buffer's capacity — the reuse
    /// hook for sweeps that rebuild similar graphs per grid point.
    pub fn reset(&mut self) {
        self.resources.clear();
        self.tasks.clear();
        self.names.clear();
        self.deps.clear();
    }

    /// Register a resource. `bandwidth` is in bytes/s and must be positive
    /// and finite; FIFO resources that only ever serve [`Service::Busy`]
    /// tasks can use [`fifo`](EventEngine::fifo) (bandwidth 1.0).
    pub fn resource(&mut self, name: &str, sharing: Sharing, bandwidth: f64) -> ResourceId {
        assert!(
            bandwidth.is_finite() && bandwidth > 0.0,
            "resource '{name}': bandwidth must be positive and finite"
        );
        let name_start = self.names.len();
        self.names.push_str(name);
        self.resources.push(ResourceSpec {
            name_start,
            name_end: self.names.len(),
            bandwidth,
            sharing,
        });
        self.resources.len() - 1
    }

    /// Exclusive FIFO resource for duration-based tasks.
    pub fn fifo(&mut self, name: &str) -> ResourceId {
        self.resource(name, Sharing::Fifo, 1.0)
    }

    /// Exclusive FIFO resource with a bandwidth (for byte transfers that
    /// serialize, e.g. a D2D link).
    pub fn fifo_bw(&mut self, name: &str, bandwidth: f64) -> ResourceId {
        self.resource(name, Sharing::Fifo, bandwidth)
    }

    /// Fair-shared bandwidth resource (e.g. the DRAM channel pool).
    pub fn fair(&mut self, name: &str, bandwidth: f64) -> ResourceId {
        self.resource(name, Sharing::Fair, bandwidth)
    }

    /// Add a task. Dependencies must already exist (this makes cycles
    /// impossible by construction).
    pub fn task(&mut self, resource: ResourceId, service: Service, deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        assert!(resource < self.resources.len(), "unknown resource {resource}");
        for &d in deps {
            assert!(d < id, "task dependency {d} does not exist yet");
        }
        let deps_start = self.deps.len();
        self.deps.extend_from_slice(deps);
        self.tasks.push(TaskSpec {
            resource,
            service,
            deps_start,
            deps_end: self.deps.len(),
        });
        id
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn n_resources(&self) -> usize {
        self.resources.len()
    }

    pub fn resource_name(&self, r: ResourceId) -> &str {
        let spec = &self.resources[r];
        &self.names[spec.name_start..spec.name_end]
    }

    fn deps_of(&self, spec: &TaskSpec) -> &[TaskId] {
        &self.deps[spec.deps_start..spec.deps_end]
    }

    /// Dependencies of task `t`, in declaration order — the static view
    /// the IR auditor ([`crate::audit`]) walks for acyclicity and
    /// dangling-dependency checks.
    pub fn task_deps(&self, t: TaskId) -> &[TaskId] {
        self.deps_of(&self.tasks[t])
    }

    /// Execute the task graph with a throwaway kernel.
    pub fn run(&self) -> RunResult {
        let mut kernel = Kernel::new();
        kernel.execute(self);
        kernel.result()
    }
}

// ───────────────────────── event queue ─────────────────────────

#[derive(Debug, Clone, Copy)]
enum EvKind {
    /// A FIFO task finished its service.
    FifoDone(TaskId),
    /// Re-examine a fair resource (some flow may have drained). The `u64`
    /// is the resource state version at scheduling time; stale checks are
    /// skipped.
    FairCheck(ResourceId, u64),
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    time: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> Ordering {
        // BinaryHeap pops the greatest element; reverse so the earliest
        // time (then the earliest sequence number) pops first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Calendar (time-wheel) event queue with exact `(time, seq)` pop order.
///
/// Events within the wheel's window land in one of [`WHEEL_SLOTS`] buckets
/// of `width` seconds each; everything else (and everything, before the
/// width is calibrated) goes to the `overflow` binary heap. Each bucket is
/// itself a binary heap, and [`pop`](TimeWheel::pop) takes the earlier of
/// the current bucket's top and the overflow top, so the order is exactly
/// what one global heap would produce — the bucket width is purely a
/// performance knob. `base` is the start time of the bucket at `cursor`,
/// and the two advance together, keeping the affine slot map
/// `slot(t) = floor((t − base) / width)` consistent for pushes.
#[derive(Debug, Clone, Default)]
struct TimeWheel {
    slots: Vec<BinaryHeap<Ev>>,
    overflow: BinaryHeap<Ev>,
    /// Bucket width in seconds; 0 = uncalibrated (all pushes overflow
    /// until a positive event time fixes the scale).
    width: f64,
    /// Start time of the bucket at `cursor`.
    base: f64,
    cursor: usize,
    /// Events currently stored in `slots` (not in `overflow`).
    in_slots: usize,
    /// Route every push to the overflow heap: exactly the legacy
    /// single-`BinaryHeap` queue. The parity tests' reference mode.
    heap_only: bool,
}

impl TimeWheel {
    /// Re-arm for a new run, keeping heap capacities.
    fn prepare(&mut self, width_hint: f64, heap_only: bool) {
        if self.slots.is_empty() {
            self.slots = (0..WHEEL_SLOTS).map(|_| BinaryHeap::new()).collect();
        }
        // A completed run drains the queue, but a panicked one may not:
        // clear defensively so a reused kernel cannot replay stale events.
        for s in &mut self.slots {
            s.clear();
        }
        self.overflow.clear();
        self.in_slots = 0;
        self.base = 0.0;
        self.cursor = 0;
        self.heap_only = heap_only;
        self.width = if width_hint.is_finite() && width_hint > 0.0 {
            width_hint
        } else {
            0.0
        };
    }

    fn push(&mut self, ev: Ev) {
        if self.heap_only {
            self.overflow.push(ev);
            return;
        }
        if self.width <= 0.0 {
            // Calibrate from the first positive event time: bucket width
            // such that this event lands well inside the window.
            if ev.time.is_finite() && ev.time > 0.0 {
                self.width = ev.time / 16.0;
            } else {
                self.overflow.push(ev);
                return;
            }
        }
        let rel = (ev.time - self.base) / self.width;
        // The negated comparison also catches NaN event times — those stay
        // on the overflow heap where `total_cmp` gives them a fixed order.
        if rel >= 0.0 && rel < WHEEL_SLOTS as f64 {
            let slot = (self.cursor + rel as usize) % WHEEL_SLOTS;
            self.slots[slot].push(ev);
            self.in_slots += 1;
        } else {
            self.overflow.push(ev);
        }
    }

    fn pop(&mut self) -> Option<Ev> {
        if self.heap_only {
            return self.overflow.pop();
        }
        loop {
            if self.in_slots == 0 {
                let ev = self.overflow.pop()?;
                // The wheel is empty: rebase its window at the popped time
                // so subsequent pushes land back in the buckets.
                if self.width > 0.0 {
                    self.base = ev.time;
                }
                return Some(ev);
            }
            if self.slots[self.cursor].is_empty() {
                self.cursor = (self.cursor + 1) % WHEEL_SLOTS;
                self.base += self.width;
                continue;
            }
            // Earliest `(time, seq)` wins between the current bucket and
            // the overflow heap; the reversed `Ord` makes greater=earlier.
            let take_overflow = match (self.overflow.peek(), self.slots[self.cursor].peek()) {
                (Some(o), Some(s)) => o > s,
                _ => false,
            };
            return if take_overflow {
                self.overflow.pop()
            } else {
                self.in_slots -= 1;
                self.slots[self.cursor].pop()
            };
        }
    }
}

// ───────────────────────── run state ─────────────────────────

#[derive(Debug, Clone)]
struct Flow {
    task: TaskId,
    remaining: f64,
    total: f64,
}

#[derive(Debug, Clone, Default)]
struct FairState {
    flows: Vec<Flow>,
    last: f64,
    version: u64,
}

/// A flow is complete when its remaining service is zero up to
/// floating-point drift accumulated over rate changes.
fn flow_done(fl: &Flow) -> bool {
    fl.remaining <= fl.total * 1e-12 + 1e-9
}

/// Reusable execution state for [`EventEngine`] graphs.
///
/// All per-run vectors (indegrees, CSR children, fair-flow lists, the
/// event queue) live here and keep their capacity across
/// [`execute`](Kernel::execute) calls, so timing many graphs through one
/// kernel allocates only on high-water-mark growth. Results are read
/// through the accessors ([`makespan`](Kernel::makespan),
/// [`finish`](Kernel::finish), …) or copied out with
/// [`result`](Kernel::result).
#[derive(Debug, Clone, Default)]
pub struct Kernel {
    // Children in CSR form: task `t`'s dependents are
    // `children[child_start[t]..child_start[t + 1]]`.
    children: Vec<TaskId>,
    child_start: Vec<usize>,
    /// CSR fill cursors (scratch for graph loading).
    fill: Vec<usize>,
    indeg: Vec<usize>,
    start: Vec<f64>,
    finish: Vec<f64>,
    busy: Vec<f64>,
    fifo_until: Vec<f64>,
    fair: Vec<FairState>,
    queue: TimeWheel,
    /// Tasks drained by the current fair-check (scratch).
    finished: Vec<TaskId>,
    seq: u64,
    events: usize,
    done: usize,
    makespan: f64,
    /// Last run's makespan, carried across runs to size the wheel buckets.
    width_hint: f64,
    heap_only: bool,
}

impl Kernel {
    pub fn new() -> Kernel {
        Kernel::default()
    }

    /// Route all events through a single binary heap (the legacy queue)
    /// instead of the calendar wheel. Pop order — and therefore every
    /// result — is identical either way; this exists so tests can assert
    /// exactly that.
    pub fn set_heap_only(&mut self, on: bool) {
        self.heap_only = on;
    }

    /// Completion time of the last task in the most recent run.
    pub fn makespan(&self) -> Seconds {
        Seconds(self.makespan)
    }

    /// Per-task service start time from the most recent run.
    pub fn start(&self, t: TaskId) -> Seconds {
        Seconds(self.start[t])
    }

    /// Per-task completion time from the most recent run.
    pub fn finish(&self, t: TaskId) -> Seconds {
        Seconds(self.finish[t])
    }

    /// Per-resource busy time from the most recent run.
    pub fn busy(&self, r: ResourceId) -> Seconds {
        Seconds(self.busy[r])
    }

    /// Events processed by the most recent run.
    pub fn events(&self) -> usize {
        self.events
    }

    /// The most recent run's results as an owned [`RunResult`]. Hot paths
    /// that only need a few numbers should prefer the accessors — this
    /// copies three vectors.
    pub fn result(&self) -> RunResult {
        RunResult {
            makespan: Seconds(self.makespan),
            start: self.start.iter().copied().map(Seconds).collect(),
            finish: self.finish.iter().copied().map(Seconds).collect(),
            busy: self.busy.iter().copied().map(Seconds).collect(),
            events: self.events,
        }
    }

    /// Re-initialize all per-run state for `eng`'s graph, keeping buffer
    /// capacity, and load the dependency structure in CSR form.
    fn load(&mut self, eng: &EventEngine) {
        let nt = eng.tasks.len();
        let nr = eng.resources.len();
        self.start.clear();
        self.start.resize(nt, 0.0);
        self.finish.clear();
        self.finish.resize(nt, 0.0);
        self.busy.clear();
        self.busy.resize(nr, 0.0);
        self.fifo_until.clear();
        self.fifo_until.resize(nr, 0.0);
        self.indeg.clear();
        self.indeg.resize(nt, 0);
        // Fair states are reset in place so their flow Vecs keep capacity.
        self.fair.truncate(nr);
        for st in &mut self.fair {
            st.flows.clear();
            st.last = 0.0;
            st.version = 0;
        }
        if self.fair.len() < nr {
            self.fair.resize_with(nr, FairState::default);
        }
        // Children CSR: count per parent, prefix-sum, fill. Filling in
        // task-id order reproduces the per-parent child order the old
        // Vec<Vec> construction had, which tie-breaks nothing but keeps
        // arrival order byte-identical anyway.
        self.child_start.clear();
        self.child_start.resize(nt + 1, 0);
        for spec in &eng.tasks {
            for &d in eng.deps_of(spec) {
                self.child_start[d + 1] += 1;
            }
        }
        for i in 0..nt {
            self.child_start[i + 1] += self.child_start[i];
        }
        self.children.clear();
        self.children.resize(eng.deps.len(), 0);
        self.fill.clear();
        self.fill.extend_from_slice(&self.child_start[..nt]);
        for (id, spec) in eng.tasks.iter().enumerate() {
            self.indeg[id] = spec.deps_end - spec.deps_start;
            for &d in eng.deps_of(spec) {
                let at = self.fill[d];
                self.children[at] = id;
                self.fill[d] = at + 1;
            }
        }
        self.queue.prepare(self.width_hint / WHEEL_SPAN_DIV, self.heap_only);
        self.finished.clear();
        self.seq = 0;
        self.events = 0;
        self.done = 0;
        self.makespan = 0.0;
    }

    fn push(&mut self, time: f64, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Ev { time, seq, kind });
    }

    /// A task's dependencies are all satisfied: hand it to its resource.
    fn arrive(&mut self, eng: &EventEngine, task: TaskId, now: f64) {
        let spec = eng.tasks[task];
        let resource = spec.resource;
        let rspec = eng.resources[resource];
        let bw = rspec.bandwidth;
        match rspec.sharing {
            Sharing::Fifo => {
                let dur = match spec.service {
                    Service::Busy(d) => d.raw(),
                    Service::Transfer(b) => b.raw() / bw,
                };
                let begin = now.max(self.fifo_until[resource]);
                self.start[task] = begin;
                let end = begin + dur;
                self.fifo_until[resource] = end;
                self.busy[resource] += dur;
                self.push(end, EvKind::FifoDone(task));
            }
            Sharing::Fair => {
                let bytes = match spec.service {
                    Service::Transfer(b) => b.raw(),
                    Service::Busy(d) => d.raw() * bw,
                };
                self.start[task] = now;
                self.advance_fair(eng, resource, now);
                self.fair[resource].flows.push(Flow {
                    task,
                    remaining: bytes,
                    total: bytes,
                });
                self.reschedule_fair(eng, resource, now);
            }
        }
    }

    /// Advance a fair resource's fluid state to time `to`.
    fn advance_fair(&mut self, eng: &EventEngine, r: ResourceId, to: f64) {
        let bw = eng.resources[r].bandwidth;
        let st = &mut self.fair[r];
        let dt = to - st.last;
        st.last = to;
        let k = st.flows.len();
        if k == 0 || dt <= 0.0 {
            return;
        }
        let rate = bw / k as f64;
        for fl in &mut st.flows {
            fl.remaining -= rate * dt;
        }
        self.busy[r] += dt;
    }

    /// Invalidate outstanding checks for `r` and schedule the next one.
    fn reschedule_fair(&mut self, eng: &EventEngine, r: ResourceId, now: f64) {
        let bw = eng.resources[r].bandwidth;
        let st = &mut self.fair[r];
        st.version += 1;
        let version = st.version;
        let k = st.flows.len();
        if k == 0 {
            return;
        }
        let min_rem = st
            .flows
            .iter()
            .map(|f| f.remaining.max(0.0))
            .fold(f64::INFINITY, f64::min);
        let rate = bw / k as f64;
        self.push(now + min_rem / rate, EvKind::FairCheck(r, version));
    }

    fn complete(&mut self, eng: &EventEngine, task: TaskId, now: f64) {
        self.finish[task] = now;
        self.done += 1;
        for i in self.child_start[task]..self.child_start[task + 1] {
            let child = self.children[i];
            self.indeg[child] -= 1;
            if self.indeg[child] == 0 {
                self.arrive(eng, child, now);
            }
        }
    }

    /// Execute `eng`'s task graph, replacing this kernel's previous run
    /// state. Results stay readable through the accessors until the next
    /// `execute`.
    pub fn execute(&mut self, eng: &EventEngine) {
        self.load(eng);
        // Roots arrive at t = 0 in creation order.
        for id in 0..eng.tasks.len() {
            if self.indeg[id] == 0 {
                self.arrive(eng, id, 0.0);
            }
        }
        let mut now = 0.0f64;
        while let Some(ev) = self.queue.pop() {
            debug_assert!(ev.time >= now, "event queue must be monotonic");
            now = ev.time;
            self.events += 1;
            match ev.kind {
                EvKind::FifoDone(task) => self.complete(eng, task, now),
                EvKind::FairCheck(r, version) => {
                    if self.fair[r].version != version {
                        continue; // superseded by a later arrival/completion
                    }
                    self.advance_fair(eng, r, now);
                    self.finished.clear();
                    {
                        // Split borrows: drain the resource's finished
                        // flows (in flow order) into the scratch list.
                        let Kernel { fair, finished, .. } = self;
                        fair[r].flows.retain(|fl| {
                            if flow_done(fl) {
                                finished.push(fl.task);
                                false
                            } else {
                                true
                            }
                        });
                    }
                    let mut i = 0;
                    while i < self.finished.len() {
                        let t = self.finished[i];
                        self.complete(eng, t, now);
                        i += 1;
                    }
                    self.reschedule_fair(eng, r, now);
                }
            }
        }
        assert_eq!(
            self.done,
            eng.tasks.len(),
            "all tasks must complete (the DAG is acyclic by construction)"
        );
        self.makespan = self.finish.iter().copied().fold(0.0, f64::max);
        if self.makespan > 0.0 {
            self.width_hint = self.makespan;
        }
    }
}

/// One engine + one kernel: the unit of buffer reuse for hot paths that
/// rebuild and time a task graph per call ([`crate::sim::system::SimPlan::time_in`],
/// [`crate::sched::pipeline::overlap_chain_event_in`],
/// [`crate::sched::onef1b::onef1b_event_in`]). A fresh arena behaves
/// exactly like fresh engines — reuse only recycles allocations, never
/// results.
#[derive(Debug, Clone, Default)]
pub struct EngineArena {
    pub engine: EventEngine,
    pub kernel: Kernel,
}

impl EngineArena {
    pub fn new() -> EngineArena {
        EngineArena::default()
    }

    /// An arena whose kernel uses the legacy single-heap event queue (see
    /// [`Kernel::set_heap_only`]) — the reference for parity tests.
    pub fn heap_only() -> EngineArena {
        let mut arena = EngineArena::default();
        arena.kernel.set_heap_only(true);
        arena
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn empty_graph_runs() {
        let eng = EventEngine::new();
        let r = eng.run();
        assert_eq!(r.makespan, Seconds::ZERO);
        assert_eq!(r.events, 0);
    }

    #[test]
    fn fifo_serializes_in_arrival_order() {
        let mut eng = EventEngine::new();
        let link = eng.fifo("link");
        let a = eng.task(link, Service::Busy(Seconds(10.0)), &[]);
        let b = eng.task(link, Service::Busy(Seconds(5.0)), &[]);
        let r = eng.run();
        // Both arrive at t=0; creation order wins the tie.
        assert_eq!(r.finish[a], Seconds(10.0));
        assert_eq!(r.finish[b], Seconds(15.0));
        assert_eq!(r.start[b], Seconds(10.0));
        assert_eq!(r.busy[link], Seconds(15.0));
        assert_eq!(r.makespan, Seconds(15.0));
    }

    #[test]
    fn dependencies_gate_start() {
        let mut eng = EventEngine::new();
        let r1 = eng.fifo("a");
        let r2 = eng.fifo("b");
        let t1 = eng.task(r1, Service::Busy(Seconds(3.0)), &[]);
        let t2 = eng.task(r2, Service::Busy(Seconds(4.0)), &[t1]);
        let t3 = eng.task(r1, Service::Busy(Seconds(1.0)), &[t2]);
        let r = eng.run();
        assert_eq!(r.finish[t1], Seconds(3.0));
        assert_eq!(r.start[t2], Seconds(3.0));
        assert_eq!(r.finish[t2], Seconds(7.0));
        assert_eq!(r.finish[t3], Seconds(8.0));
    }

    #[test]
    fn fifo_transfer_uses_bandwidth() {
        let mut eng = EventEngine::new();
        let link = eng.fifo_bw("link", 4.0);
        let t = eng.task(link, Service::Transfer(Bytes(8.0)), &[]);
        let r = eng.run();
        assert_eq!(r.finish[t], Seconds(2.0));
    }

    #[test]
    fn fair_share_splits_bandwidth() {
        // bw = 2 B/s. Flow A (4 B) starts at t=0; flow B (4 B) is gated to
        // t=1. Fluid sharing: A alone on [0,1) moves 2 B; both share on
        // [1,3) at 1 B/s each, so A drains its last 2 B at t=3; B then runs
        // alone at 2 B/s and drains its remaining 2 B at t=4.
        let mut eng = EventEngine::new();
        let gate = eng.fifo("gate");
        let dram = eng.fair("dram", 2.0);
        let a = eng.task(dram, Service::Transfer(Bytes(4.0)), &[]);
        let g = eng.task(gate, Service::Busy(Seconds(1.0)), &[]);
        let b = eng.task(dram, Service::Transfer(Bytes(4.0)), &[g]);
        let r = eng.run();
        assert!((r.finish[a].raw() - 3.0).abs() < 1e-9, "{:?}", r.finish);
        assert!((r.finish[b].raw() - 4.0).abs() < 1e-9, "{:?}", r.finish);
        // The resource was active the whole [0,4] interval.
        assert!((r.busy[dram].raw() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fair_equal_flows_finish_together() {
        let mut eng = EventEngine::new();
        let dram = eng.fair("dram", 2.0);
        let a = eng.task(dram, Service::Transfer(Bytes(4.0)), &[]);
        let b = eng.task(dram, Service::Transfer(Bytes(4.0)), &[]);
        let r = eng.run();
        assert!((r.finish[a].raw() - 4.0).abs() < 1e-9);
        assert!((r.finish[b].raw() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fair_single_flow_is_exact() {
        // One flow at a time through a chain: completion times are exact
        // multiples — the uncongested path must not accumulate drift.
        let mut eng = EventEngine::new();
        let dram = eng.fair("dram", 1e9);
        let mut prev: Option<TaskId> = None;
        for _ in 0..100 {
            let deps: Vec<TaskId> = prev.into_iter().collect();
            prev = Some(eng.task(dram, Service::Transfer(Bytes(1e6)), &deps));
        }
        let r = eng.run();
        let expect = 100.0 * 1e6 / 1e9;
        assert!(
            (r.makespan.raw() - expect).abs() / expect < 1e-9,
            "{} vs {expect}",
            r.makespan.raw()
        );
    }

    #[test]
    fn zero_service_completes_at_dep_finish() {
        let mut eng = EventEngine::new();
        let res = eng.fifo("r");
        let dram = eng.fair("d", 1.0);
        let a = eng.task(res, Service::Busy(Seconds(2.0)), &[]);
        let b = eng.task(res, Service::Busy(Seconds::ZERO), &[a]);
        let c = eng.task(dram, Service::Transfer(Bytes::ZERO), &[a]);
        let r = eng.run();
        assert_eq!(r.finish[b], Seconds(2.0));
        assert_eq!(r.finish[c], Seconds(2.0));
    }

    #[test]
    fn reruns_are_deterministic() {
        let mut eng = EventEngine::new();
        let link = eng.fifo("link");
        let dram = eng.fair("dram", 3.0);
        let mut last = Vec::new();
        for i in 0..20 {
            let deps = last.clone();
            let t = if i % 2 == 0 {
                eng.task(link, Service::Busy(Seconds(0.5 + i as f64)), &deps)
            } else {
                eng.task(dram, Service::Transfer(Bytes(7.0 * i as f64)), &deps)
            };
            if i % 3 == 0 {
                last = vec![t];
            } else {
                last.push(t);
            }
        }
        let r1 = eng.run();
        let r2 = eng.run();
        assert_eq!(r1.finish, r2.finish);
        assert_eq!(r1.start, r2.start);
        assert_eq!(r1.events, r2.events);
    }

    /// The canonical two-stage pipeline (n DRAM chunks feeding n compute
    /// slots) lands exactly on the analytic `max(A,B) + min(A,B)/n`.
    #[test]
    fn pipeline_identity_matches_closed_form() {
        prop::check("2-stage pipeline == max+min/n", 64, |g| {
            let a_total = g.f64_range(1e-4, 1.0);
            let b_total = g.f64_range(1e-4, 1.0);
            let n = g.usize_range(1, 64);
            let mut eng = EventEngine::new();
            let pkg = eng.fifo("pkg");
            let dram = eng.fifo("dram");
            let a = a_total / n as f64;
            let b = b_total / n as f64;
            let mut prev_d: Option<TaskId> = None;
            let mut prev_p: Option<TaskId> = None;
            for _ in 0..n {
                let deps_d: Vec<TaskId> = prev_d.into_iter().collect();
                let d = eng.task(dram, Service::Busy(Seconds(b)), &deps_d);
                let mut deps_p = vec![d];
                if let Some(p) = prev_p {
                    deps_p.push(p);
                }
                let p = eng.task(pkg, Service::Busy(Seconds(a)), &deps_p);
                prev_d = Some(d);
                prev_p = Some(p);
            }
            let got = eng.run().makespan.raw();
            let want = a_total.max(b_total) + a_total.min(b_total) / n as f64;
            prop::assert_close(got, want, 1e-9, format!("n={n}"))
        });
    }

    #[test]
    #[should_panic(expected = "does not exist yet")]
    fn forward_dependencies_are_rejected() {
        let mut eng = EventEngine::new();
        let r = eng.fifo("r");
        eng.task(r, Service::Busy(Seconds(1.0)), &[5]);
    }

    #[test]
    fn resource_accessors() {
        let mut eng = EventEngine::new();
        let r = eng.fair("dram", 2.0);
        assert_eq!(eng.resource_name(r), "dram");
        assert_eq!(eng.n_resources(), 1);
        assert_eq!(eng.n_tasks(), 0);
    }

    /// Build a randomized DAG mixing FIFO and fair resources, gated
    /// dependencies and zero-service tasks.
    fn random_graph(g: &mut prop::Gen) -> EventEngine {
        let mut eng = EventEngine::new();
        let n_fifo = g.usize_range(1, 3);
        let n_fair = g.usize_range(1, 3);
        let mut res = Vec::new();
        for i in 0..n_fifo {
            res.push(eng.fifo_bw(&format!("f{i}"), g.f64_range(0.5, 8.0)));
        }
        for i in 0..n_fair {
            res.push(eng.fair(&format!("d{i}"), g.f64_range(0.5, 8.0)));
        }
        let n = g.usize_range(2, 60);
        for t in 0..n {
            let r = *g.pick(&res);
            let svc = if g.bool() {
                Service::Busy(Seconds(g.f64_range(0.0, 5.0)))
            } else {
                Service::Transfer(Bytes(g.f64_range(0.0, 40.0)))
            };
            let mut deps = Vec::new();
            if t > 0 {
                for _ in 0..g.usize_range(0, t.min(3)) {
                    let d = g.usize_range(0, t - 1);
                    if !deps.contains(&d) {
                        deps.push(d);
                    }
                }
            }
            eng.task(r, svc, &deps);
        }
        eng
    }

    /// The calendar wheel's pop order is exactly the legacy heap's: every
    /// start/finish/busy value and the event count are bitwise identical.
    #[test]
    fn wheel_matches_heap_only_order() {
        prop::check("time wheel == single heap", 64, |g| {
            let eng = random_graph(g);
            let mut wheel = Kernel::new();
            let mut heap = Kernel::new();
            heap.set_heap_only(true);
            wheel.execute(&eng);
            heap.execute(&eng);
            let same = wheel.result().finish.iter().zip(heap.result().finish.iter())
                .all(|(a, b)| a.raw().to_bits() == b.raw().to_bits());
            prop::assert_prop(
                same && wheel.events() == heap.events()
                    && wheel.makespan().raw().to_bits() == heap.makespan().raw().to_bits(),
                format!(
                    "wheel {}/{} events vs heap {}",
                    wheel.makespan().raw(),
                    wheel.events(),
                    heap.events()
                ),
            )
        });
    }

    /// A kernel reused across different graphs gives bitwise the same
    /// answers as a fresh kernel, and `reset` fully clears the builder.
    #[test]
    fn kernel_and_engine_reuse_are_bitwise_identical() {
        prop::check("kernel reuse == fresh kernel", 32, |g| {
            let mut arena = EngineArena::new();
            // Pollute the arena with an unrelated graph first.
            let warm = random_graph(g);
            arena.kernel.execute(&warm);
            let eng = random_graph(g);
            let fresh = eng.run();
            arena.engine = eng.clone();
            arena.kernel.execute(&arena.engine);
            let reused = arena.kernel.result();
            let same_finish = fresh
                .finish
                .iter()
                .zip(reused.finish.iter())
                .all(|(a, b)| a.raw().to_bits() == b.raw().to_bits());
            let same_busy = fresh
                .busy
                .iter()
                .zip(reused.busy.iter())
                .all(|(a, b)| a.raw().to_bits() == b.raw().to_bits());
            prop::assert_prop(
                same_finish && same_busy && fresh.events == reused.events,
                format!("{} vs {} events", fresh.events, reused.events),
            )
        });
    }

    #[test]
    fn reset_clears_the_graph() {
        let mut eng = EventEngine::new();
        let r = eng.fifo("r");
        eng.task(r, Service::Busy(Seconds(1.0)), &[]);
        eng.reset();
        assert_eq!(eng.n_tasks(), 0);
        assert_eq!(eng.n_resources(), 0);
        let out = eng.run();
        assert_eq!(out.makespan, Seconds::ZERO);
        // The builder is fully usable after a reset.
        let r2 = eng.fair("dram", 2.0);
        assert_eq!(eng.resource_name(r2), "dram");
        let t = eng.task(r2, Service::Transfer(Bytes(4.0)), &[]);
        assert_eq!(eng.run().finish[t], Seconds(2.0));
    }
}
