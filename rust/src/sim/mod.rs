//! System-level simulator: combines the per-die compute model, the NoP
//! collective costs, the DRAM stream model and the fusion/overlap schedule
//! into end-to-end training latency and energy (the paper's evaluation
//! testbed, §VI).
//!
//! Timing is produced by one of two backends ([`system::EngineKind`]): the
//! closed-form **analytic** path (Table III parity) or the **event** path
//! running on the discrete-event core in [`engine`].

pub mod engine;
pub mod system;
pub mod weak_scaling;

pub use engine::{EventEngine, RunResult, Service, Sharing};
pub use system::{simulate, simulate_engine, EngineKind, LatencyBreakdown, SimResult};
pub use weak_scaling::{weak_scaling_sweep, WeakScalingPoint};
