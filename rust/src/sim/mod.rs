//! System-level simulator: combines the per-die compute model, the NoP
//! collective costs, the DRAM stream model and the fusion/overlap schedule
//! into end-to-end training latency and energy (the paper's evaluation
//! testbed, §VI).
//!
//! Timing is produced by one of two backends ([`system::EngineKind`]): the
//! closed-form **analytic** path (Table III parity) or the **event** path
//! running on the discrete-event core in [`engine`].

//!
//! Simulation proceeds in three phases — **plan** (workload decomposition,
//! fusion schedule), **price** (per-group stage costs, traffic, energy)
//! and **time** (a backend turns the stage chain into wall-clock). The
//! first two are captured in an immutable [`system::SimPlan`]; the
//! [`sweep`] module runs grids of points in parallel with memoized plans.
//!
//! [`cluster`] lifts the same split to a cluster of packages: per-stage
//! sub-plans (priced once via the plan cache) compose with the 1F1B
//! pipeline schedule and DP gradient all-reduce over the shared
//! inter-package fabric.
//!
//! The public entrypoint over all of this is the **Scenario API**
//! ([`crate::scenario`]): one declarative [`crate::scenario::Scenario`]
//! value covering single-package and cluster targets, a unified
//! [`crate::scenario::evaluate`], and a [`crate::scenario::ScenarioGrid`]
//! replacing the former `SweepGrid`/`ClusterGrid` pair.

pub mod cluster;
pub mod engine;
pub mod sweep;
pub mod system;
pub mod weak_scaling;

pub use cluster::{simulate_cluster, ClusterPlan, ClusterResult};
pub use engine::{EngineArena, EventEngine, Kernel, RunResult, Service, Sharing};
pub use sweep::{
    parallel_map, parallel_map_with, pareto_front, run_points, run_points_threads, PlanCache,
    PlanSig, SweepPoint,
};
pub use system::{
    simulate, simulate_engine, simulate_with, EngineKind, LatencyBreakdown, PlanOptions, SimPlan,
    SimResult,
};
pub use weak_scaling::{weak_scaling_sweep, WeakScalingPoint};
