//! System-level simulator: combines the per-die compute model, the NoP
//! collective costs, the DRAM stream model and the fusion/overlap schedule
//! into end-to-end training latency and energy (the paper's evaluation
//! testbed, §VI).

pub mod system;
pub mod weak_scaling;

pub use system::{simulate, LatencyBreakdown, SimResult};
pub use weak_scaling::{weak_scaling_sweep, WeakScalingPoint};
