//! # Hecaton
//!
//! Reproduction of *"Hecaton: Training Large Language Models with Scalable
//! Waferscale Chiplet Systems"* (cs.AR 2024) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate contains two cooperating halves:
//!
//! 1. **The chiplet system simulator** — the paper's evaluation testbed,
//!    rebuilt from scratch: hardware models ([`arch`]), a typed
//!    communication IR lowered per topology ([`comm`]) onto the
//!    step-level NoP collective simulator ([`nop`]), per-die compute
//!    timing ([`compute`]), a DRAM stream model ([`memory`]), the
//!    transformer workload decomposition ([`workload`]), the four
//!    tensor-parallel methods ([`parallel`]) emitting [`comm::CommOp`]s,
//!    Hecaton's fusion/overlap scheduling ([`sched`]) and the
//!    system-level latency/energy simulator ([`sim`], [`energy`]).
//!    Timing runs on one of **two engine backends**
//!    ([`sim::system::EngineKind`]): the *analytic* closed forms of paper
//!    Table III, or the *event* backend — a discrete-event core
//!    ([`sim::engine`]: monotonic event queue, FIFO link/package
//!    resources, fair-shared DRAM channels) that reproduces the closed
//!    forms within 1% on uncongested meshes and additionally models what
//!    they cannot: link contention, shared DRAM channels, skewed meshes
//!    and cross-group overlap slack (see the `congestion` report).
//!
//! 2. **The functional distributed-training engine** — real numerics:
//!    the [`runtime`] loads AOT-compiled JAX/Pallas artifacts via PJRT, the
//!    [`coordinator`] executes the paper's Algorithm 1 (2D-tiled linear
//!    layers with row/column all-gather + reduce-scatter) across simulated
//!    dies running on threads, and [`train`] drives end-to-end training of
//!    a small transformer with a loss curve.
//!
//! The public entrypoint over the simulator half is the **Scenario API**
//! ([`scenario`]): a declarative [`scenario::Scenario`] (model ×
//! package-or-cluster × method × engine × options, built via a validating
//! [`scenario::ScenarioBuilder`] or loaded from a TOML scenario file) and
//! one [`scenario::evaluate`] returning a unified [`scenario::Evaluation`].
//! Grids over scenario axes ([`scenario::ScenarioGrid`]) power
//! `hecaton sweep`, `hecaton run` and every report driver, and the
//! branch-and-bound [`search`] subsystem explores the same grids with
//! admissible-bound pruning (`hecaton search`) instead of exhaustive
//! evaluation. The
//! [`prelude`] makes the whole surface usable in a handful of lines:
//!
//! ```no_run
//! use hecaton::prelude::*;
//!
//! let s = Scenario::builder(model_preset("llama2-70b").unwrap())
//!     .dies(256)
//!     .method(Method::Hecaton)
//!     .build()
//!     .unwrap();
//! println!("{}", evaluate(&s).unwrap().latency());
//! ```
//!
//! Experiment drivers reproducing every table and figure of the paper's
//! evaluation live in [`report`].

pub mod util;
pub mod config;
pub mod arch;
pub mod comm;
pub mod nop;
pub mod compute;
pub mod memory;
pub mod workload;
pub mod parallel;
pub mod sched;
pub mod energy;
pub mod net;
pub mod sim;
pub mod scenario;
pub mod search;
pub mod runtime;
pub mod coordinator;
pub mod train;
pub mod report;
pub mod audit;
pub mod lint;
pub mod bench;
pub mod cli;

/// One-import surface for library users: scenario construction,
/// evaluation, grids, and the config/result types they touch.
///
/// ```no_run
/// use hecaton::prelude::*;
///
/// let s = Scenario::builder(model_preset("tinyllama-1.1b").unwrap())
///     .dies(16)
///     .cluster(4, 2, 2)
///     .engine(EngineKind::Event)
///     .build()
///     .unwrap();
/// let eval = evaluate(&s).unwrap();
/// println!("{} at {:.0} tokens/s", eval.latency(), eval.tokens_per_sec());
/// ```
pub mod prelude {
    pub use crate::config::cluster::{
        cluster_preset, ClusterConfig, FabricTopo, InterKind, InterPkgLink,
    };
    pub use crate::config::presets::model_preset;
    pub use crate::config::{DramKind, HardwareConfig, ModelConfig, PackageKind, TopologyKind};
    pub use crate::memory::sram::OccupancyReport;
    pub use crate::nop::analytic::Method;
    pub use crate::sched::checkpoint::Checkpoint;
    pub use crate::scenario::{
        evaluate, run_all, run_on, Evaluation, Scenario, ScenarioBuilder, ScenarioGrid, Target,
    };
    pub use crate::search::{Objective, SearchConfig, SearchOutcome};
    pub use crate::sim::cluster::ClusterResult;
    pub use crate::sim::sweep::PlanCache;
    pub use crate::sim::system::{EngineKind, PlanOptions, SimResult};
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
