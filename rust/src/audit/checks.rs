//! Pure invariant checks over the simulator's intermediate structures.
//!
//! Each function here takes an already-built IR fragment — a dependency
//! table, a lowered traffic phase, a bound pair, an SRAM timeline, the
//! loader schema — and returns human-readable violation messages. The
//! functions are pure so they serve three callers identically: the
//! `hecaton audit` driver ([`crate::audit`]), the `debug_assertions`
//! hooks wired into the builders themselves, and the mutation-fixture
//! tests below that prove each check actually fires.

use crate::comm::TrafficPhase;
use crate::memory::sram::SramTimeline;
use crate::nop::CollectiveKind;
use crate::search::bound::CostBound;

/// Relative tolerance for float cross-checks. Every compared pair is
/// produced by two evaluation orders of the same f64 arithmetic, so the
/// honest disagreement is a few ulps; 1e-9 leaves five orders of
/// magnitude of headroom while still catching any real modeling drift.
pub const REL_TOL: f64 = 1e-9;

/// `a ≈ b` under [`REL_TOL`], with an absolute floor of one unit so
/// near-zero quantities (bytes, seconds) compare sanely.
pub fn rel_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1.0)
}

/// Check a task dependency table: `deps[id]` lists the tasks `id` waits
/// on. Valid tables are exactly the DAGs the event engine can run —
/// every dep exists, precedes its dependent (tasks are pushed in
/// topological order), and no cycle closes. The cycle scan is an
/// independent three-color DFS so a table that *also* breaks the
/// precedence rule still gets its cycles named.
pub fn dep_table_violations(deps: &[Vec<usize>]) -> Vec<String> {
    let n = deps.len();
    let mut out = Vec::new();
    for (id, ds) in deps.iter().enumerate() {
        for &d in ds {
            if d >= n {
                out.push(format!("task {id} depends on task {d}, which does not exist"));
            } else if d >= id {
                out.push(format!("task {id} depends on task {d}, which does not precede it"));
            }
        }
    }
    // 0 = unvisited, 1 = on the current DFS path, 2 = finished.
    let mut color = vec![0u8; n];
    for start in 0..n {
        if color[start] != 0 {
            continue;
        }
        color[start] = 1;
        let mut stack: Vec<(usize, usize)> = vec![(start, 0)];
        while let Some(&(node, i)) = stack.last() {
            let ds = &deps[node];
            if i < ds.len() {
                stack.last_mut().expect("non-empty stack").1 += 1;
                let d = ds[i];
                if d >= n {
                    continue; // already reported above
                }
                match color[d] {
                    0 => {
                        color[d] = 1;
                        stack.push((d, 0));
                    }
                    1 => out.push(format!("dependency cycle through tasks {node} and {d}")),
                    _ => {}
                }
            } else {
                color[node] = 2;
                stack.pop();
            }
        }
    }
    out
}

/// Check byte conservation across a lowering: the wire bytes a lowered
/// schedule actually moves (`scale × Σ per_link × |links|`) must equal
/// the collective's closed-form total — `(n−1)·V` for all-gather,
/// reduce-scatter, broadcast and reduce, `2(n−1)·V` for all-reduce. A
/// topology is free to *route* however it likes; it is not free to drop
/// or invent traffic.
pub fn conservation_violation(phase: &TrafficPhase) -> Option<String> {
    let n = phase.op.group.size() as f64;
    let volume = phase.op.volume.raw();
    let expected = match phase.op.kind {
        CollectiveKind::AllGather
        | CollectiveKind::ReduceScatter
        | CollectiveKind::Broadcast
        | CollectiveKind::Reduce => (n - 1.0) * volume,
        CollectiveKind::AllReduce => 2.0 * (n - 1.0) * volume,
        // No topology lowers these yet; there is no law to check.
        CollectiveKind::Gather | CollectiveKind::Scatter => return None,
    };
    let moved: f64 = phase
        .schedule
        .steps
        .iter()
        .map(|s| s.per_link.raw() * s.links.count() as f64)
        .sum();
    let actual = phase.scale * moved;
    if rel_close(actual, expected) {
        return None;
    }
    Some(format!(
        "{:?} over {:?} moves {actual:.3} wire bytes, expected {expected:.3}",
        phase.op.kind, phase.op.group
    ))
}

/// Check the bound sandwich `tier0 ≤ tier1 ≤ anchor`: a refinement may
/// only tighten a lower bound, and an admissible latency bound can
/// never exceed the serialized cost of a concrete plan (`anchor_s`).
/// All four bound components must also be finite and non-negative, or
/// the branch-and-bound comparisons they feed are meaningless.
pub fn bound_violations(lb0: CostBound, lb1: CostBound, anchor_s: f64) -> Vec<String> {
    let mut out = Vec::new();
    for (name, v) in [
        ("tier-0 latency", lb0.latency_s),
        ("tier-0 energy", lb0.energy_j),
        ("tier-1 latency", lb1.latency_s),
        ("tier-1 energy", lb1.energy_j),
    ] {
        if !v.is_finite() || v < 0.0 {
            out.push(format!("{name} bound is {v}, not a finite non-negative number"));
        }
    }
    if lb1.latency_s < lb0.latency_s {
        out.push(format!(
            "tier-1 latency bound {} is below tier-0's {} — refinement must only tighten",
            lb1.latency_s, lb0.latency_s
        ));
    }
    if lb1.energy_j < lb0.energy_j {
        out.push(format!(
            "tier-1 energy bound {} is below tier-0's {} — refinement must only tighten",
            lb1.energy_j, lb0.energy_j
        ));
    }
    if lb1.latency_s > anchor_s * (1.0 + REL_TOL) {
        out.push(format!(
            "tier-1 latency bound {} exceeds the plan's serialized anchor {anchor_s} — \
             the bound is not admissible",
            lb1.latency_s
        ));
    }
    out
}

/// Check a replayed SRAM timeline: non-empty, every sample finite with
/// non-negative occupancy, and sample times non-decreasing (the replay
/// walks the schedule in execution order, so time travel means the
/// span accounting double-counted or went negative).
pub fn timeline_violation(timeline: &SramTimeline) -> Option<String> {
    if timeline.samples.is_empty() {
        return Some("timeline has no samples".to_string());
    }
    let mut prev = f64::NEG_INFINITY;
    for (i, s) in timeline.samples.iter().enumerate() {
        let t = s.t.raw();
        let total = s.total().raw();
        if !t.is_finite() || !total.is_finite() {
            return Some(format!("sample {i} is not finite (t={t}, total={total})"));
        }
        if total < 0.0 {
            return Some(format!("sample {i} has negative occupancy {total}"));
        }
        if t + 1e-12 < prev {
            return Some(format!(
                "sample {i} at t={t} precedes the previous sample at t={prev}"
            ));
        }
        prev = prev.max(t);
    }
    None
}

/// Check the scenario-file loader schema against the axes the grid
/// runner and the search driver actually consume: every consumer axis
/// must be reachable from its section, and every schema key must feed a
/// consumer. Either direction failing means a TOML key silently does
/// nothing — the schema-exhaustiveness contract.
pub fn schema_violations(
    schema: &[(&str, &[&str])],
    grid_axes: &[&str],
    search_keys: &[&str],
) -> Vec<String> {
    let mut out = Vec::new();
    section_violations(schema, "sweep", grid_axes, &mut out);
    section_violations(schema, "search", search_keys, &mut out);
    out
}

fn section_violations(
    schema: &[(&str, &[&str])],
    section: &str,
    expected: &[&str],
    out: &mut Vec<String>,
) {
    let Some((_, keys)) = schema.iter().find(|(s, _)| *s == section) else {
        out.push(format!("loader schema has no [{section}] section"));
        return;
    };
    for k in expected {
        if !keys.contains(k) {
            out.push(format!("axis '{k}' is unreachable from [{section}] in the loader schema"));
        }
    }
    for k in *keys {
        if !expected.contains(k) {
            out.push(format!("[{section}] key '{k}' feeds no consumer axis"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommOp, Group, Topology};
    use crate::config::TopologyKind;
    use crate::memory::sram::SramSample;
    use crate::util::{Bytes, Seconds};

    #[test]
    fn valid_dep_table_is_clean() {
        let deps = vec![vec![], vec![0], vec![0, 1]];
        assert!(dep_table_violations(&deps).is_empty());
    }

    #[test]
    fn cyclic_dep_table_names_the_cycle() {
        // 0 → 1 → 0: both a precedence violation (0 depends on 1) and a
        // genuine cycle; the DFS must report the cycle independently.
        let v = dep_table_violations(&[vec![1], vec![0]]);
        assert!(
            v.iter().any(|m| m.contains("dependency cycle through tasks")),
            "{v:?}"
        );
        assert!(v.iter().any(|m| m.contains("does not precede")), "{v:?}");
    }

    #[test]
    fn dangling_dep_is_reported() {
        let v = dep_table_violations(&[vec![5]]);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("task 5, which does not exist"), "{}", v[0]);
    }

    #[test]
    fn real_lowerings_conserve_bytes() {
        let vol = Bytes::mib(3.0);
        for topo in [TopologyKind::Mesh2d, TopologyKind::Torus2d] {
            for op in [
                CommOp::all_gather(Group::BypassRing { n: 4 }, vol),
                CommOp::reduce_scatter(Group::BypassRing { n: 5 }, vol),
                CommOp::all_reduce(Group::FlatRing { n: 16 }, vol),
                CommOp::all_gather(Group::FlatRing { n: 9 }, vol),
                CommOp::all_reduce(Group::Grid { side: 4 }, vol),
                CommOp::broadcast(Group::Line { n: 4 }, vol),
                CommOp::new(CollectiveKind::Reduce, Group::Line { n: 3 }, vol),
            ] {
                let phase = topo.lower(op);
                assert_eq!(conservation_violation(&phase), None, "{topo:?} {op:?}");
            }
        }
    }

    #[test]
    fn dropped_step_breaks_conservation() {
        let op = CommOp::all_gather(Group::BypassRing { n: 4 }, Bytes::mib(1.0));
        let mut phase = TopologyKind::Mesh2d.lower(op);
        phase.schedule.steps.pop();
        let v = conservation_violation(&phase).expect("dropped bytes must be detected");
        assert!(v.contains("wire bytes"), "{v}");
    }

    #[test]
    fn scaled_schedule_conserves_through_the_scale() {
        // The flat ring's all-reduce replays one phase schedule twice
        // (scale 2.0) — conservation must account for the scale.
        let op = CommOp::all_reduce(Group::FlatRing { n: 8 }, Bytes::mib(2.0));
        let phase = TopologyKind::Mesh2d.lower(op);
        assert!(phase.scale > 1.0, "fixture assumes a scaled lowering");
        assert_eq!(conservation_violation(&phase), None);
    }

    #[test]
    fn admissible_bounds_are_clean() {
        let lb0 = CostBound { latency_s: 1.0, energy_j: 10.0 };
        let lb1 = CostBound { latency_s: 2.0, energy_j: 12.0 };
        assert!(bound_violations(lb0, lb1, 3.0).is_empty());
    }

    #[test]
    fn loosened_refinement_is_reported() {
        let lb0 = CostBound { latency_s: 2.0, energy_j: 10.0 };
        let lb1 = CostBound { latency_s: 1.0, energy_j: 8.0 };
        let v = bound_violations(lb0, lb1, 3.0);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v[0].contains("refinement must only tighten"), "{}", v[0]);
    }

    #[test]
    fn bound_above_anchor_is_inadmissible() {
        let lb0 = CostBound { latency_s: 1.0, energy_j: 1.0 };
        let lb1 = CostBound { latency_s: 5.0, energy_j: 1.0 };
        let v = bound_violations(lb0, lb1, 4.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("not admissible"), "{}", v[0]);
    }

    #[test]
    fn non_finite_bound_is_reported() {
        let lb0 = CostBound { latency_s: f64::NAN, energy_j: 1.0 };
        let lb1 = CostBound { latency_s: 1.0, energy_j: 1.0 };
        assert!(!bound_violations(lb0, lb1, 2.0).is_empty());
    }

    fn sample(t: f64, acts: f64) -> SramSample {
        SramSample {
            t: Seconds(t),
            weights: Bytes(100.0),
            acts: Bytes(acts),
            staging: Bytes::ZERO,
        }
    }

    #[test]
    fn monotone_timeline_is_clean() {
        let tl = SramTimeline {
            samples: vec![sample(0.0, 1.0), sample(1.0, 2.0), sample(1.0, 3.0)],
            capacity: Bytes::mib(1.0),
        };
        assert_eq!(timeline_violation(&tl), None);
    }

    #[test]
    fn time_travel_is_reported() {
        let tl = SramTimeline {
            samples: vec![sample(2.0, 1.0), sample(1.0, 1.0)],
            capacity: Bytes::mib(1.0),
        };
        let v = timeline_violation(&tl).expect("decreasing time must be detected");
        assert!(v.contains("precedes the previous sample"), "{v}");
    }

    #[test]
    fn negative_occupancy_is_reported() {
        let tl = SramTimeline {
            samples: vec![sample(0.0, -500.0)],
            capacity: Bytes::mib(1.0),
        };
        let v = timeline_violation(&tl).expect("negative occupancy must be detected");
        assert!(v.contains("negative occupancy"), "{v}");
    }

    #[test]
    fn empty_timeline_is_reported() {
        let tl = SramTimeline { samples: vec![], capacity: Bytes::mib(1.0) };
        assert!(timeline_violation(&tl).is_some());
    }

    #[test]
    fn doctored_schema_is_reported_both_directions() {
        let sweep: &[&str] = &["models", "stray"];
        let search: &[&str] = &["objective"];
        let schema: &[(&str, &[&str])] = &[("sweep", sweep), ("search", search)];
        let v = schema_violations(schema, &["models", "meshes"], &["objective"]);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|m| m.contains("axis 'meshes' is unreachable")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("key 'stray' feeds no consumer")), "{v:?}");
    }

    #[test]
    fn missing_schema_section_is_reported() {
        let v = schema_violations(&[], &["models"], &["objective"]);
        assert!(v.iter().any(|m| m.contains("no [sweep] section")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("no [search] section")), "{v:?}");
    }
}
