//! IR auditor: static verification of the simulator's invariant
//! contracts (`hecaton audit`).
//!
//! Layer 2 of the static-analysis subsystem (Layer 1, the source-level
//! determinism lint, is [`crate::lint`]). Where the property tests
//! *sample* the contracts at runtime, the auditor *states* them over
//! the intermediate structures a scenario actually builds and checks
//! every instance:
//!
//! - **task-graph** — the event and packet task DAGs are acyclic, every
//!   dependency exists and precedes its dependent, every task's
//!   resources are registered.
//! - **byte-conservation** — every collective lowering moves exactly
//!   the closed-form wire bytes; the cluster fabric's all-reduce
//!   bandwidth term is invariant across fabric topologies.
//! - **bound-sandwich** — the search's admissible bounds satisfy
//!   `tier0 ≤ tier1 ≤ serialized plan anchor`.
//! - **sram-monotonic** — the replayed SRAM timeline is finite,
//!   non-negative and time-ordered, and its peak matches the plan's
//!   occupancy report.
//! - **schema** — the scenario-file loader schema and the grid/search
//!   consumers agree key-for-key (no TOML key silently does nothing).
//!
//! The same predicates (in [`checks`]) back `debug_assertions` hooks at
//! the build sites themselves — `comm::Topology::lower`,
//! `net::PacketNet::run`, `search::bound::tier1_*`,
//! `memory::sram::replay` — so debug test runs re-verify the contracts
//! on every structure they build, while release binaries pay nothing
//! and rely on `hecaton audit` in CI.

pub mod checks;

use std::fmt;

use crate::comm::{CommOp, Group, Topology};
use crate::config::{FabricTopo, HardwareConfig};
use crate::memory::{sram, DramModel};
use crate::net::lower::build_packet_net;
use crate::net::NetParams;
use crate::nop::collective::build_event_graph;
use crate::nop::{CollectiveKind, CollectiveSchedule};
use crate::scenario::Scenario;
use crate::sched::{overlap, StageTimes};
use crate::search::bound::{tier0, tier1_cluster, tier1_package};
use crate::sim::{ClusterPlan, PlanCache, SimPlan};
use crate::util::{Bytes, Seconds};

/// One audit finding: which contract, on which structure, and what
/// exactly is wrong.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditFinding {
    /// Name of the violated check (a [`CHECKS`] entry).
    pub check: &'static str,
    /// The structure the violation was found on.
    pub context: String,
    /// Human-readable statement of the violation.
    pub detail: String,
}

impl fmt::Display for AuditFinding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.check, self.context, self.detail)
    }
}

/// One registered audit check, for `hecaton info` and `--checks`.
pub struct Check {
    pub name: &'static str,
    /// One-line summary (shown by `hecaton info`).
    pub summary: &'static str,
    /// Longer statement of the contract.
    pub docs: &'static str,
}

/// The full check registry, in stable display order.
pub const CHECKS: &[Check] = &[
    Check {
        name: "task-graph",
        summary: "event/packet task DAGs are acyclic with valid deps",
        docs: "Every task dependency must name an existing task pushed \
               before its dependent, and no dependency cycle may close; \
               packet tasks must also run on registered nodes and route \
               over registered links. A violation would deadlock or \
               misprice the event backends.",
    },
    Check {
        name: "byte-conservation",
        summary: "every lowering moves exactly the collective's bytes",
        docs: "A topology lowering chooses routes, not volumes: the wire \
               bytes of the lowered schedule (scale x sum of per-link x \
               link count) must equal (n-1)V for all-gather, \
               reduce-scatter, broadcast and reduce, and 2(n-1)V for \
               all-reduce. The cluster fabric's all-reduce is checked \
               for topology invariance of its bandwidth term.",
    },
    Check {
        name: "bound-sandwich",
        summary: "search bounds satisfy tier0 <= tier1 <= plan anchor",
        docs: "The branch-and-bound search is exact only if its bounds \
               are admissible: the tier-1 refinement may only tighten \
               tier-0, and neither may exceed the serialized cost of \
               the concrete plan they bound. All components must be \
               finite and non-negative.",
    },
    Check {
        name: "sram-monotonic",
        summary: "SRAM timelines are time-ordered with a consistent peak",
        docs: "The replayed per-die occupancy timeline must be \
               non-empty, finite, non-negative and non-decreasing in \
               time, and its peak must match the occupancy report the \
               plan carries — otherwise feasibility gating and \
               checkpoint resolution judged a different schedule than \
               the one priced.",
    },
    Check {
        name: "schema",
        summary: "scenario-file schema and its consumers agree key-for-key",
        docs: "Every [sweep]/[search] key the TOML loader accepts must \
               feed a grid axis or search knob, and every axis must be \
               reachable from a key — a mismatch means a scenario file \
               can name a knob that silently does nothing.",
    },
];

/// Names of all registered checks, in display order.
pub fn check_names() -> Vec<&'static str> {
    CHECKS.iter().map(|c| c.name).collect()
}

/// Look up a check by name.
pub fn check(name: &str) -> Option<&'static Check> {
    CHECKS.iter().find(|c| c.name == name)
}

/// Checks that need no scenario: schema exhaustiveness between the TOML
/// loader and the grid/search consumers.
pub fn audit_static() -> Vec<AuditFinding> {
    checks::schema_violations(
        crate::config::file::schema(),
        crate::scenario::GRID_AXES,
        crate::search::SEARCH_FILE_KEYS,
    )
    .into_iter()
    .map(|detail| AuditFinding {
        check: "schema",
        context: "loader schema".to_string(),
        detail,
    })
    .collect()
}

/// Audit one scenario: lower its collective matrix, build both task
/// graphs, price its plan, and check every contract instance. Returns
/// the findings; errors only when the scenario itself cannot be planned
/// (which the planner reports better than the auditor could).
pub fn audit_scenario(s: &Scenario) -> crate::Result<Vec<AuditFinding>> {
    let mut out = Vec::new();
    audit_package(s, &mut out);
    audit_cluster(s, &mut out)?;
    Ok(out)
}

/// The collective matrix the package audit lowers: every (collective,
/// group) combination the topology zoo supports, shaped to `hw`'s mesh,
/// at a round and a deliberately awkward volume.
fn planner_shapes(hw: &HardwareConfig) -> Vec<CommOp> {
    let rows = hw.mesh_rows;
    let cols = hw.mesh_cols;
    let dies = hw.n_dies();
    let side = rows.min(cols);
    let mut ops = Vec::new();
    for vol in [Bytes::mib(8.0), Bytes(12_345_678.0)] {
        ops.push(CommOp::all_gather(Group::BypassRing { n: rows }, vol));
        ops.push(CommOp::reduce_scatter(Group::BypassRing { n: cols }, vol));
        ops.push(CommOp::all_reduce(Group::FlatRing { n: dies }, vol));
        ops.push(CommOp::all_gather(Group::FlatRing { n: dies }, vol));
        ops.push(CommOp::all_reduce(Group::Grid { side }, vol));
        ops.push(CommOp::broadcast(Group::Line { n: rows }, vol));
        ops.push(CommOp::new(CollectiveKind::Reduce, Group::Line { n: cols }, vol));
    }
    ops.retain(|op| op.group.size() >= 2);
    ops
}

/// Package-level audit: conservation across the lowering matrix, both
/// task graphs over the lowered schedules, the package bound sandwich,
/// and the plan's SRAM timeline.
fn audit_package(s: &Scenario, out: &mut Vec<AuditFinding>) {
    let hw = s.hw();
    let mut schedules: Vec<CollectiveSchedule> = Vec::new();
    for op in planner_shapes(hw) {
        let phase = hw.topology.lower(op);
        if let Some(detail) = checks::conservation_violation(&phase) {
            out.push(AuditFinding {
                check: "byte-conservation",
                context: format!("{} lowering", hw.topology.name()),
                detail,
            });
        }
        schedules.push(phase.schedule);
    }
    let refs: Vec<&CollectiveSchedule> = schedules.iter().collect();

    let eng = build_event_graph(&refs, &hw.link);
    let deps: Vec<Vec<usize>> = (0..eng.n_tasks()).map(|t| eng.task_deps(t).to_vec()).collect();
    for detail in checks::dep_table_violations(&deps) {
        out.push(AuditFinding {
            check: "task-graph",
            context: "event graph".to_string(),
            detail,
        });
    }

    let net = build_packet_net(&refs, &hw.link, &NetParams::default());
    let deps: Vec<Vec<usize>> = (0..net.n_tasks()).map(|t| net.task_deps(t).to_vec()).collect();
    for detail in checks::dep_table_violations(&deps) {
        out.push(AuditFinding {
            check: "task-graph",
            context: "packet graph".to_string(),
            detail,
        });
    }
    if let Err(detail) = net.validate() {
        out.push(AuditFinding {
            check: "task-graph",
            context: "packet graph".to_string(),
            detail,
        });
    }

    let lb0 = tier0(s);
    let plan = SimPlan::build(&s.model, hw, s.method, s.opts);
    let lb1 = tier1_package(&plan, hw, lb0);
    let anchor = plan
        .breakdown
        .total()
        .raw()
        .max(DramModel::new(hw).stream_time(plan.dram_bytes).raw())
        .max(lb0.latency_s);
    for detail in checks::bound_violations(lb0, lb1, anchor) {
        out.push(AuditFinding {
            check: "bound-sandwich",
            context: "package bound".to_string(),
            detail,
        });
    }
    audit_plan_sram(&plan, hw, "package plan", out);
}

/// Cluster-level audit (no-op for package scenarios): the cluster bound
/// sandwich, every stage plan's SRAM timeline, and fabric-topology
/// invariance of the DP all-reduce's bandwidth term.
fn audit_cluster(s: &Scenario, out: &mut Vec<AuditFinding>) -> crate::Result<()> {
    let Some(cluster) = s.cluster_config() else {
        return Ok(());
    };
    let cache = PlanCache::new();
    let plan = ClusterPlan::build(&s.model, cluster, s.method, s.opts, &cache)?;
    let hw = &plan.cluster.package_hw;

    let lb0 = tier0(s);
    let lb1 = tier1_cluster(&plan, lb0);
    let stage0 = &plan.stage_plans[0];
    let anchor = stage0
        .breakdown
        .total()
        .raw()
        .max(DramModel::new(hw).stream_time(stage0.dram_bytes).raw())
        .max(lb0.latency_s);
    for detail in checks::bound_violations(lb0, lb1, anchor) {
        out.push(AuditFinding {
            check: "bound-sandwich",
            context: "cluster bound".to_string(),
            detail,
        });
    }

    for (i, sp) in plan.stage_plans.iter().enumerate() {
        audit_plan_sram(sp, hw, &format!("cluster stage {i} plan"), out);
    }

    // Fabric invariance: the all-reduce time minus the topology's own
    // latency term is pure bandwidth — flipping the fabric topology at
    // equal bandwidth must not change it. The hop counts are duplicated
    // in `audit_ar_hops` so this checks the simulator against an
    // independent statement of the lowering contract.
    let dp = plan.cluster.dp;
    let mut flipped = plan.clone();
    let mut inter = plan.cluster.inter.clone();
    inter.topo = match inter.topo {
        FabricTopo::PointToPoint => FabricTopo::FatTree,
        FabricTopo::FatTree => FabricTopo::PointToPoint,
    };
    flipped.retarget_inter(inter);
    for stage in 0..plan.stage_plans.len() {
        if plan.spec.allreduce_bytes(stage, dp).raw() <= 0.0 {
            continue;
        }
        let a = bandwidth_term(&plan, stage, dp);
        let b = bandwidth_term(&flipped, stage, dp);
        if !checks::rel_close(a, b) {
            out.push(AuditFinding {
                check: "byte-conservation",
                context: format!("fabric all-reduce, stage {stage}"),
                detail: format!(
                    "bandwidth term {a:.6e}s under {} vs {b:.6e}s under {} — \
                     the fabric topology changed the bytes moved",
                    plan.cluster.inter.topo.name(),
                    flipped.cluster.inter.topo.name()
                ),
            });
        }
    }
    Ok(())
}

/// Stage `stage`'s all-reduce time with the fabric's latency term
/// subtracted — what remains is volume over bandwidth.
fn bandwidth_term(plan: &ClusterPlan, stage: usize, dp: usize) -> f64 {
    plan.allreduce_time(stage).raw()
        - plan.cluster.inter.hop_latency().raw() * audit_ar_hops(dp, plan.cluster.inter.topo)
}

/// Fabric hops on the DP all-reduce critical path, restated
/// independently of [`ClusterPlan`]'s private rule: a point-to-point
/// ring serializes `2(dp−1)` hops, a fat-tree runs halving-doubling in
/// `2⌈log₂ dp⌉` switched rounds.
fn audit_ar_hops(dp: usize, topo: FabricTopo) -> f64 {
    let dp = dp as f64;
    match topo {
        FabricTopo::PointToPoint => 2.0 * (dp - 1.0),
        FabricTopo::FatTree => 2.0 * dp.log2().ceil(),
    }
}

/// Replay `plan`'s SRAM timeline under freshly recomputed analytic
/// stage spans and check ordering plus peak agreement with the plan's
/// own occupancy report.
fn audit_plan_sram(
    plan: &SimPlan,
    hw: &HardwareConfig,
    context: &str,
    out: &mut Vec<AuditFinding>,
) {
    let dram_model = DramModel::new(hw);
    let spans: Vec<Seconds> = plan
        .stages
        .iter()
        .map(|st| {
            overlap(StageTimes {
                on_package: st.on_package,
                dram: dram_model.stream_time(st.dram_bytes),
                n_minibatches: st.n_minibatches,
            })
            .latency
        })
        .collect();
    let timeline = sram::replay(plan.occupancy_shape(), &plan.groups, &plan.stages, &spans);
    if let Some(detail) = checks::timeline_violation(&timeline) {
        out.push(AuditFinding {
            check: "sram-monotonic",
            context: context.to_string(),
            detail,
        });
    }
    let replayed = timeline.peak().total();
    if !checks::rel_close(replayed.raw(), plan.occupancy.peak.raw()) {
        out.push(AuditFinding {
            check: "sram-monotonic",
            context: context.to_string(),
            detail: format!(
                "replayed occupancy peak {replayed} disagrees with the plan's reported {}",
                plan.occupancy.peak
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{cluster_preset, model_preset, DramKind, PackageKind, TopologyKind};
    use crate::nop::analytic::Method;
    use crate::sim::EngineKind;

    #[test]
    fn registry_is_consistent() {
        let names = check_names();
        assert_eq!(names.len(), CHECKS.len());
        for c in CHECKS {
            assert!(!c.summary.is_empty() && !c.docs.is_empty(), "{}", c.name);
            assert_eq!(check(c.name).map(|x| x.name), Some(c.name));
        }
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate check names");
        assert!(check("no-such-check").is_none());
    }

    #[test]
    fn finding_display_names_check_and_context() {
        let f = AuditFinding {
            check: "task-graph",
            context: "event graph".to_string(),
            detail: "task 3 depends on task 9, which does not exist".to_string(),
        };
        assert_eq!(
            f.to_string(),
            "[task-graph] event graph: task 3 depends on task 9, which does not exist"
        );
    }

    #[test]
    fn loader_schema_audits_clean() {
        let findings = audit_static();
        assert!(findings.is_empty(), "{findings:?}");
    }

    fn package_scenario(topo: TopologyKind) -> Scenario {
        let model = model_preset("tinyllama-1.1b").expect("preset");
        let mut hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
        hw.topology = topo;
        Scenario::package(model, hw, Method::Hecaton, EngineKind::Analytic)
    }

    #[test]
    fn mesh_package_scenario_audits_clean() {
        let findings = audit_scenario(&package_scenario(TopologyKind::Mesh2d)).expect("plans");
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn torus_package_scenario_audits_clean() {
        let findings = audit_scenario(&package_scenario(TopologyKind::Torus2d)).expect("plans");
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cluster_scenario_audits_clean() {
        let (model, cluster) = cluster_preset("tiny-cluster").expect("preset");
        let s = Scenario::cluster(model, cluster, Method::Hecaton, EngineKind::Analytic);
        let findings = audit_scenario(&s).expect("plans");
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn bad_packet_graph_fails_validation() {
        // The packet builders do not check routes — `validate` must.
        let mut net = crate::net::PacketNet::new(NetParams::default());
        let n = net.node("die0");
        net.work(n, Seconds(1e-6), &[]);
        net.flow_with_debt(&[7], Bytes(1e6), Seconds::ZERO, &[]);
        let err = net.validate().expect_err("unregistered link must be caught");
        assert!(err.contains("unregistered link 7"), "{err}");
    }
}
