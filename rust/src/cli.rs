//! `hecaton` command-line interface.
//!
//! Subcommands:
//! * `simulate`  — run the system simulator on one (model, hardware, method)
//! * `sweep`     — run a scenario grid in parallel (memoized planning,
//!   Pareto-annotated table/CSV/JSON output)
//! * `reproduce` — regenerate a paper table/figure (fig8, fig9, …)
//! * `train`     — functional distributed training with a loss curve
//! * `info`      — show presets and the resolved configuration

use anyhow::anyhow;

use crate::config::cluster::{cluster_preset, cluster_presets, ClusterConfig, InterPkgLink};
use crate::config::presets::{eval_models, model_preset};
use crate::config::{DramKind, HardwareConfig, ModelConfig, PackageKind};
use crate::nop::analytic::Method;
use crate::sim::cluster::{run_cluster_points, simulate_cluster, ClusterGrid};
use crate::sim::sweep::{self, PlanCache, SweepGrid};
use crate::sim::system::{simulate_with, EngineKind, SimOptions};
use crate::util::cli::{parse_list, App, CliError, CommandSpec, Matches};
use crate::util::fmt::pct;
use crate::util::table::Table;

/// Build the CLI application spec.
pub fn app() -> App {
    App::new("hecaton", "scalable waferscale-chiplet LLM training (paper reproduction)")
        .command(
            CommandSpec::new("simulate", "simulate one training batch")
                .opt("model", "llama2-70b", "model preset (see `hecaton info`)")
                .opt("dies", "256", "number of computing dies (square) or use --mesh")
                .opt("mesh", "", "explicit RxC mesh, e.g. 2x8")
                .opt("package", "standard", "packaging: standard | advanced")
                .opt("dram", "ddr5-6400", "dram: ddr4-3200 | ddr5-6400 | hbm2")
                .opt("method", "hecaton", "hecaton | flat-ring | torus-ring | optimus")
                .opt("engine", "analytic", "timing backend: analytic | event | event-prefetch")
                .opt("n-packages", "1", "packages in the cluster (must equal dp x pp)")
                .opt("dp", "1", "data-parallel replicas across packages")
                .opt("pp", "1", "pipeline stages across packages (1F1B)")
                .opt("inter-bw", "substrate", "inter-package fabric: substrate | optical | <GB/s>")
                .opt("config", "", "TOML config file (overrides the above)"),
        )
        .command(
            CommandSpec::new("sweep", "run a scenario grid in parallel (plan cache + Pareto)")
                .opt("models", "tinyllama-1.1b", "comma list of model presets, or 'all'")
                .opt("meshes", "4x4", "comma list of RxC meshes and/or square die counts, e.g. 4x4,2x8,64")
                .opt("packages", "standard", "comma list: standard,advanced or 'all'")
                .opt("drams", "ddr5-6400", "comma list: ddr4-3200,ddr5-6400,hbm2 or 'all'")
                .opt("methods", "all", "comma list of TP methods, or 'all'")
                .opt("engines", "analytic", "comma list of timing backends, or 'all'")
                .opt("n-packages", "1", "comma list of cluster package counts (dp x pp)")
                .opt("dp", "1", "comma list of data-parallel widths")
                .opt("pp", "1", "comma list of pipeline depths")
                .opt("inter-bw", "substrate", "comma list of fabrics: substrate | optical | <GB/s>")
                .opt("threads", "0", "worker threads (0 = one per core; 1 = serial)")
                .opt("format", "table", "output format: table | csv | json"),
        )
        .command(
            CommandSpec::new("reproduce", "regenerate a paper table/figure")
                .pos("experiment", "fig8 | fig9 | fig10 | fig11 | table3 | table4 | gpu | weak | cluster | all"),
        )
        .command(
            CommandSpec::new("train", "functional distributed training (real numerics)")
                .opt("model", "tiny", "tiny | e2e-100m")
                .opt("mesh", "2x2", "die mesh RxC (artifacts must exist)")
                .opt("steps", "20", "training steps")
                .opt("lr", "0.5", "learning rate")
                .opt("seed", "1234", "seed")
                .opt("task", "next-token", "next-token | induction"),
        )
        .command(CommandSpec::new("info", "list presets and hardware defaults"))
}

/// Entry point used by `main.rs`.
pub fn run(args: &[String]) -> crate::Result<i32> {
    let app = app();
    let Some(m) = app.parse(args).map_err(|e| anyhow!("{e}"))? else {
        return Ok(0); // help printed
    };
    match m.command.as_str() {
        "simulate" => cmd_simulate(&m),
        "sweep" => cmd_sweep(&m),
        "reproduce" => cmd_reproduce(&m),
        "train" => cmd_train(&m),
        "info" => cmd_info(),
        other => Err(anyhow!("unhandled command {other}")),
    }?;
    Ok(0)
}

fn parse_mesh(s: &str) -> crate::Result<(usize, usize)> {
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| anyhow!("mesh must be RxC, e.g. 4x4"))?;
    let (r, c): (usize, usize) = (r.trim().parse()?, c.trim().parse()?);
    if r == 0 || c == 0 {
        return Err(anyhow!(
            "degenerate mesh {r}x{c}: need at least 1 row and 1 column of dies"
        ));
    }
    Ok((r, c))
}

fn cmd_simulate(m: &Matches) -> crate::Result<()> {
    let (model, hw) = if !m.value("config").is_empty() {
        let setup = crate::config::file::load(m.value("config"))?;
        (setup.model, setup.hardware)
    } else {
        let model = model_preset(m.value("model"))
            .ok_or_else(|| anyhow!("unknown model '{}'", m.value("model")))?;
        let package = PackageKind::parse(m.value("package"))
            .ok_or_else(|| anyhow!("bad package"))?;
        let dram = DramKind::parse(m.value("dram")).ok_or_else(|| anyhow!("bad dram"))?;
        let hw = if !m.value("mesh").is_empty() {
            let (r, c) = parse_mesh(m.value("mesh"))?;
            HardwareConfig::try_mesh(r, c, package, dram)?
        } else {
            HardwareConfig::try_square(m.parse_value("dies")?, package, dram)?
        };
        (model, hw)
    };
    let method = Method::parse(m.value("method")).ok_or_else(|| anyhow!("bad method"))?;
    let engine = EngineKind::parse(m.value("engine"))
        .ok_or_else(|| anyhow!("bad engine '{}'", m.value("engine")))?;

    // Cluster knobs (`--n-packages`, matching the sweep axis; `--package`
    // remains the packaging *kind*): anything beyond the degenerate 1×1×1
    // shape routes through the cluster simulator; the defaults keep the
    // established single-package path (and its output) untouched. The
    // fabric spec is validated even when unused, so a typo never passes
    // silently.
    let packages: usize = m.parse_value("n-packages")?;
    let dp: usize = m.parse_value("dp")?;
    let pp: usize = m.parse_value("pp")?;
    let inter = InterPkgLink::parse(m.value("inter-bw")).ok_or_else(|| {
        anyhow!("bad inter-bw '{}' (substrate | optical | <GB/s>)", m.value("inter-bw"))
    })?;
    if packages != 1 || dp != 1 || pp != 1 {
        let cluster = ClusterConfig::try_new(hw, packages, dp, pp, inter)?;
        return print_cluster_simulation(&model, &cluster, method, engine);
    }

    let r = simulate_with(
        &model,
        &hw,
        method,
        SimOptions {
            engine,
            ..SimOptions::default()
        },
    );

    let mut t = Table::new(&["metric", "value"]).label_first();
    let lat = r.latency.raw();
    t.row(crate::table_row!["model", model.name]);
    t.row(crate::table_row![
        "mesh",
        format!("{}x{} ({} dies, {})", hw.mesh_rows, hw.mesh_cols, r.dies, hw.package.name())
    ]);
    t.row(crate::table_row!["method", method.name()]);
    t.row(crate::table_row!["engine", r.engine.name()]);
    t.row(crate::table_row!["batch latency", r.latency]);
    t.row(crate::table_row![
        "  compute",
        format!("{} ({})", r.breakdown.compute, pct(r.breakdown.compute.raw(), lat, 1))
    ]);
    t.row(crate::table_row![
        "  NoP transmission",
        format!(
            "{} ({})",
            r.breakdown.nop_transmission,
            pct(r.breakdown.nop_transmission.raw(), lat, 1)
        )
    ]);
    t.row(crate::table_row![
        "  NoP link latency",
        format!("{} ({})", r.breakdown.nop_link, pct(r.breakdown.nop_link.raw(), lat, 2))
    ]);
    t.row(crate::table_row![
        "  exposed DRAM",
        format!("{} ({})", r.breakdown.dram_exposed, pct(r.breakdown.dram_exposed.raw(), lat, 1))
    ]);
    t.row(crate::table_row!["energy / batch", r.energy_total]);
    t.row(crate::table_row![
        "throughput",
        format!("{:.0} tokens/s", r.tokens_per_sec(&model))
    ]);
    t.row(crate::table_row![
        "achieved compute",
        crate::util::fmt::flops(r.achieved_flops())
    ]);
    t.row(crate::table_row![
        "efficiency",
        format!("{} /W", crate::util::fmt::flops(r.flops_per_watt()))
    ]);
    t.row(crate::table_row![
        "PE utilization (worst block)",
        match r.min_utilization {
            Some(u) => format!("{:.1}%", 100.0 * u),
            None => "—".to_string(),
        }
    ]);
    t.row(crate::table_row![
        "mini-batch",
        format!("{} tokens x {}", r.minibatch_tokens, r.n_minibatches)
    ]);
    t.row(crate::table_row![
        "SRAM act/weight peak",
        format!("{} / {}", r.sram.act_peak, r.sram.weight_peak)
    ]);
    t.row(crate::table_row![
        "feasible",
        if r.feasible() { "yes" } else { "NO (SRAM overflow or layout)" }
    ]);
    println!("{}", t.render());
    Ok(())
}

/// `hecaton simulate` with cluster knobs: one cluster batch, rendered with
/// the hybrid-parallelism breakdown.
fn print_cluster_simulation(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    method: Method,
    engine: EngineKind,
) -> crate::Result<()> {
    let r = simulate_cluster(model, cluster, method, engine)?;
    let lat = r.latency.raw();
    let hw = &cluster.package_hw;
    let mut t = Table::new(&["metric", "value"]).label_first();
    t.row(crate::table_row!["model", model.name.clone()]);
    t.row(crate::table_row![
        "cluster",
        format!(
            "{} packages (dp={} x pp={}), {} dies total",
            r.packages, r.dp, r.pp, r.total_dies
        )
    ]);
    t.row(crate::table_row![
        "package",
        format!("{}x{} dies, {}", hw.mesh_rows, hw.mesh_cols, hw.package.name())
    ]);
    t.row(crate::table_row![
        "fabric",
        format!("{:.0} GB/s, {}", cluster.inter.gbs(), cluster.inter.latency)
    ]);
    t.row(crate::table_row!["method (in-package TP)", method.name()]);
    t.row(crate::table_row!["engine", r.engine.name()]);
    t.row(crate::table_row!["batch latency", r.latency]);
    t.row(crate::table_row![
        "  pipeline bubble",
        format!("{} ({})", r.bubble, pct(r.bubble.raw(), lat, 1))
    ]);
    t.row(crate::table_row![
        "  stage p2p fill",
        format!("{} ({})", r.p2p, pct(r.p2p.raw(), lat, 2))
    ]);
    t.row(crate::table_row![
        "  grad all-reduce",
        format!("{} ({})", r.grad_allreduce, pct(r.grad_allreduce.raw(), lat, 1))
    ]);
    t.row(crate::table_row!["stage latency", r.stage.latency]);
    t.row(crate::table_row!["1F1B microbatches", r.microbatches]);
    t.row(crate::table_row!["energy / batch", r.energy_total]);
    t.row(crate::table_row![
        "throughput",
        format!("{:.0} tokens/s", r.tokens_per_sec())
    ]);
    t.row(crate::table_row![
        "feasible",
        if r.feasible() { "yes" } else { "NO (SRAM overflow or layout)" }
    ]);
    println!("{}", t.render());
    Ok(())
}

fn parse_model_list(s: &str) -> crate::Result<Vec<ModelConfig>> {
    if s.eq_ignore_ascii_case("all") {
        return eval_models()
            .iter()
            .map(|n| model_preset(n).ok_or_else(|| anyhow!("unknown model '{n}'")))
            .collect();
    }
    parse_list(s, "model", |n| {
        model_preset(n).ok_or_else(|| CliError(format!("unknown model '{n}'")))
    })
    .map_err(|e| anyhow!("{e}"))
}

/// Meshes come as `RxC` layouts and/or bare square die counts; both are
/// validated (no zero rows/cols, square counts must be perfect squares).
fn parse_mesh_list(s: &str) -> crate::Result<Vec<(usize, usize)>> {
    parse_list(s, "mesh", |item| {
        if item.contains('x') {
            parse_mesh(item).map_err(|e| CliError(format!("{e:#}")))
        } else {
            let n: usize = item
                .parse()
                .map_err(|e| CliError(format!("bad mesh '{item}': {e}")))?;
            let hw = HardwareConfig::try_square(n, PackageKind::Standard, DramKind::Ddr5_6400)
                .map_err(|e| CliError(format!("{e:#}")))?;
            Ok((hw.mesh_rows, hw.mesh_cols))
        }
    })
    .map_err(|e| anyhow!("{e}"))
}

fn parse_package_list(s: &str) -> crate::Result<Vec<PackageKind>> {
    if s.eq_ignore_ascii_case("all") {
        return Ok(vec![PackageKind::Standard, PackageKind::Advanced]);
    }
    parse_list(s, "package", |x| {
        PackageKind::parse(x).ok_or_else(|| CliError(format!("bad package '{x}'")))
    })
    .map_err(|e| anyhow!("{e}"))
}

fn parse_dram_list(s: &str) -> crate::Result<Vec<DramKind>> {
    if s.eq_ignore_ascii_case("all") {
        return Ok(vec![DramKind::Ddr4_3200, DramKind::Ddr5_6400, DramKind::Hbm2]);
    }
    parse_list(s, "dram", |x| {
        DramKind::parse(x).ok_or_else(|| CliError(format!("bad dram '{x}'")))
    })
    .map_err(|e| anyhow!("{e}"))
}

fn parse_method_list(s: &str) -> crate::Result<Vec<Method>> {
    if s.eq_ignore_ascii_case("all") {
        return Ok(Method::all().to_vec());
    }
    parse_list(s, "method", |x| {
        Method::parse(x).ok_or_else(|| CliError(format!("bad method '{x}'")))
    })
    .map_err(|e| anyhow!("{e}"))
}

fn parse_engine_list(s: &str) -> crate::Result<Vec<EngineKind>> {
    if s.eq_ignore_ascii_case("all") {
        return Ok(EngineKind::all().to_vec());
    }
    parse_list(s, "engine", |x| {
        EngineKind::parse(x).ok_or_else(|| CliError(format!("bad engine '{x}'")))
    })
    .map_err(|e| anyhow!("{e}"))
}

/// Positive-integer comma lists (the `--n-packages/--dp/--pp` axes).
fn parse_usize_list(s: &str, what: &str) -> crate::Result<Vec<usize>> {
    parse_list(s, what, |x| {
        let v: usize = x
            .parse()
            .map_err(|e| CliError(format!("bad {what} '{x}': {e}")))?;
        if v == 0 {
            return Err(CliError(format!("{what} must be >= 1")));
        }
        Ok(v)
    })
    .map_err(|e| anyhow!("{e}"))
}

fn parse_inter_list(s: &str) -> crate::Result<Vec<InterPkgLink>> {
    parse_list(s, "inter-bw", |x| {
        InterPkgLink::parse(x)
            .ok_or_else(|| CliError(format!("bad inter-bw '{x}' (substrate | optical | <GB/s>)")))
    })
    .map_err(|e| anyhow!("{e}"))
}

fn cmd_sweep(m: &Matches) -> crate::Result<()> {
    // Validate the output format *before* burning cores on the grid.
    let format = m.value("format");
    if !matches!(format, "table" | "csv" | "json") {
        return Err(anyhow!("bad format '{format}' (table | csv | json)"));
    }
    let threads: usize = m.parse_value("threads")?;
    let models = parse_model_list(m.value("models"))?;
    let meshes = parse_mesh_list(m.value("meshes"))?;
    let pkg_kinds = parse_package_list(m.value("packages"))?;
    let drams = parse_dram_list(m.value("drams"))?;
    let methods = parse_method_list(m.value("methods"))?;
    let engines = parse_engine_list(m.value("engines"))?;

    // Cluster axes: the degenerate defaults (1×1×1, one fabric) keep the
    // established single-package sweep (and its exact output) untouched.
    // The fabric list is validated even when unused, so a typo never
    // passes silently — and a *multi-valued* fabric list is itself a
    // cluster axis, never dropped.
    let n_packages = parse_usize_list(m.value("n-packages"), "n-packages")?;
    let dp = parse_usize_list(m.value("dp"), "dp")?;
    let pp = parse_usize_list(m.value("pp"), "pp")?;
    let inter = parse_inter_list(m.value("inter-bw"))?;
    if n_packages != [1] || dp != [1] || pp != [1] || inter.len() > 1 {
        let grid = ClusterGrid {
            models,
            meshes,
            packages: pkg_kinds,
            drams,
            methods,
            engines,
            n_packages,
            dp,
            pp,
            inter,
        };
        let (points, skipped) = grid.points()?;
        if points.is_empty() {
            return Err(anyhow!(
                "cluster sweep grid is empty ({skipped} combinations skipped: \
                 dp x pp must equal n-packages, dp must divide the batch, pp <= layers)"
            ));
        }
        let t0 = std::time::Instant::now();
        let cache = PlanCache::new();
        let results = run_cluster_points(&cache, &points, threads)?;
        let wall = t0.elapsed();
        let front = sweep::pareto_front(
            &results
                .iter()
                .map(|r| (r.latency.raw(), r.energy_total.raw()))
                .collect::<Vec<_>>(),
        );
        match format {
            "table" => println!(
                "{}",
                crate::sim::cluster::render_cluster_table(&points, &results, &front)
            ),
            "csv" => print!(
                "{}",
                crate::sim::cluster::render_cluster_csv(&points, &results, &front)
            ),
            "json" => print!(
                "{}",
                crate::sim::cluster::render_cluster_json(&points, &results, &front)
            ),
            _ => unreachable!("format validated above"),
        }
        eprintln!(
            "cluster sweep: {} points ({} combinations skipped), {} plans built, {} cache hits, {:?} wall",
            points.len(),
            skipped,
            cache.misses(),
            cache.hits(),
            wall
        );
        return Ok(());
    }

    let grid = SweepGrid {
        models,
        meshes,
        packages: pkg_kinds,
        drams,
        methods,
        engines,
    };
    if grid.is_empty() {
        return Err(anyhow!("empty sweep grid"));
    }
    let points = grid.points()?;
    let t0 = std::time::Instant::now();
    let cache = PlanCache::new();
    let results = sweep::run_points_on(&cache, &points, threads);
    let wall = t0.elapsed();
    let front = sweep::pareto_front(
        &results
            .iter()
            .map(|r| (r.latency.raw(), r.energy_total.raw()))
            .collect::<Vec<_>>(),
    );
    match format {
        "table" => println!("{}", sweep::render_table(&points, &results, &front)),
        "csv" => print!("{}", sweep::render_csv(&points, &results, &front)),
        "json" => print!("{}", sweep::render_json(&points, &results, &front)),
        _ => unreachable!("format validated above"),
    }
    // Run stats go to stderr so stdout stays machine-parseable.
    eprintln!(
        "sweep: {} points, {} plans built, {} cache hits, {:?} wall",
        points.len(),
        cache.misses(),
        cache.hits(),
        wall
    );
    Ok(())
}

fn cmd_reproduce(m: &Matches) -> crate::Result<()> {
    let exp = m.pos(0).ok_or_else(|| anyhow!("which experiment? (fig8|...|all)"))?;
    if exp == "all" {
        for id in crate::report::experiments() {
            println!("{}", crate::report::run(id)?);
        }
    } else {
        println!("{}", crate::report::run(exp)?);
    }
    Ok(())
}

fn cmd_train(m: &Matches) -> crate::Result<()> {
    use crate::coordinator::{coord_model, Coordinator, MeshCfg};
    use crate::train::data::Corpus;

    let model = coord_model(m.value("model"))
        .ok_or_else(|| anyhow!("model '{}' has no functional preset", m.value("model")))?;
    let (rows, cols) = parse_mesh(m.value("mesh"))?;
    let tokens = match model.name.as_str() {
        "tiny" => 64,
        _ => model.seq_len,
    };
    let seed: u64 = m.parse_value("seed")?;
    let mut corpus = match m.value("task") {
        "induction" => Corpus::induction(model.vocab, model.seq_len, seed),
        _ => Corpus::next_token(model.vocab, model.seq_len, seed),
    };
    let cfg = MeshCfg::new(model, rows, cols, tokens);
    println!(
        "spawning {}x{} die mesh for '{}' ({} tokens/mini-batch)…",
        rows, cols, cfg.model.name, tokens
    );
    let mut coord = Coordinator::new(cfg, seed)?;
    let logs = crate::train::train(
        &mut coord,
        &mut corpus,
        crate::train::TrainCfg {
            steps: m.parse_value("steps")?,
            lr: m.parse_value("lr")?,
            seed,
        },
    )?;
    let mut t = Table::new(&["step", "loss", "wall"]).label_first();
    for l in &logs {
        t.row(crate::table_row![l.step, format!("{:.4}", l.loss), format!("{:?}", l.wall)]);
    }
    println!("{}", t.render());
    coord.shutdown()?;
    Ok(())
}

fn cmd_info() -> crate::Result<()> {
    let mut t = Table::new(&["model", "hidden", "layers", "heads", "seq", "params"])
        .with_title("Model presets")
        .label_first();
    for name in eval_models() {
        let m = model_preset(name).unwrap();
        t.row(crate::table_row![
            m.name,
            m.hidden,
            m.layers,
            m.heads,
            m.seq_len,
            crate::util::fmt::count(m.total_params())
        ]);
    }
    println!("{}", t.render());
    let die = HardwareConfig::paper_die();
    println!(
        "Die: {} MACs/cycle @ {:.0} MHz = {} peak; {} + {} buffers; {} mm2",
        die.macs_per_cycle(),
        die.freq_hz / 1e6,
        crate::util::fmt::flops(die.peak_flops()),
        die.weight_buf,
        die.act_buf,
        die.area_mm2
    );
    let methods: Vec<&str> = Method::all().iter().map(|m| m.name()).collect();
    println!("TP methods: {}", methods.join(" | "));
    let engines: Vec<&str> = EngineKind::all().iter().map(|e| e.name()).collect();
    println!("Engine backends: {}", engines.join(" | "));
    println!(
        "Sweep axes: --models --meshes --packages --drams --methods --engines \
         (comma lists; most accept 'all'), --threads, --format table|csv|json"
    );
    println!(
        "Cluster knobs (simulate + sweep): --n-packages/--dp/--pp \
         (dp x pp must equal the package count; TP stays in-package), \
         --inter-bw substrate|optical|<GB/s>"
    );
    println!("Cluster presets (see `hecaton reproduce cluster`):");
    for name in cluster_presets() {
        let (m, c) = cluster_preset(name).expect("preset resolves");
        println!(
            "  {name}: {} on {} x {}x{}-die packages, dp={} x pp={}, {:.0} GB/s fabric",
            m.name,
            c.packages,
            c.package_hw.mesh_rows,
            c.package_hw.mesh_cols,
            c.dp,
            c.pp,
            c.inter.gbs()
        );
    }
    println!("Functional (train) presets: tiny, e2e-100m — see aot.py DEPLOYMENTS");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn app_parses_all_subcommands() {
        let a = app();
        assert!(a.parse(&argv(&["simulate", "--model", "tiny"])).unwrap().is_some());
        assert!(a.parse(&argv(&["sweep", "--models", "tiny"])).unwrap().is_some());
        assert!(a.parse(&argv(&["reproduce", "fig8"])).unwrap().is_some());
        assert!(a.parse(&argv(&["train", "--steps", "3"])).unwrap().is_some());
        assert!(a.parse(&argv(&["info"])).unwrap().is_some());
        assert!(a.parse(&argv(&["bogus"])).is_err());
    }

    #[test]
    fn parse_mesh_forms() {
        assert_eq!(parse_mesh("4x4").unwrap(), (4, 4));
        assert_eq!(parse_mesh("2x8").unwrap(), (2, 8));
        assert!(parse_mesh("44").is_err());
        // Regression: degenerate meshes are parse errors, not downstream
        // panics / division by zero.
        assert!(parse_mesh("0x4").is_err());
        assert!(parse_mesh("4x0").is_err());
    }

    /// Regression: `simulate` rejects degenerate hardware with a clean
    /// error (no panic), for both --mesh and --dies forms.
    #[test]
    fn simulate_rejects_degenerate_hardware() {
        let a = app();
        for args in [
            vec!["simulate", "--mesh", "0x4"],
            vec!["simulate", "--mesh", "4x0"],
            vec!["simulate", "--dies", "0"],
            vec!["simulate", "--dies", "12"], // not a perfect square
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            let r = cmd_simulate(&m);
            assert!(r.is_err(), "{args:?} should error cleanly");
        }
    }

    /// Regression: breakdown percentages guard against a zero/non-finite
    /// denominator instead of printing NaN%.
    #[test]
    fn pct_guards_zero_total() {
        assert_eq!(pct(0.5, 0.0, 1), "—");
        assert_eq!(pct(0.5, f64::NAN, 1), "—");
        assert_eq!(pct(f64::NAN, 1.0, 1), "—");
        assert_eq!(pct(0.5, 2.0, 1), "25.0%");
        assert_eq!(pct(0.25, 1.0, 2), "25.00%");
    }

    #[test]
    fn sweep_list_parsers() {
        assert_eq!(parse_model_list("all").unwrap().len(), eval_models().len());
        assert_eq!(
            parse_model_list("tinyllama-1.1b, llama2-7b").unwrap().len(),
            2
        );
        assert!(parse_model_list("nope").is_err());
        assert_eq!(parse_mesh_list("4x4,16,2x8").unwrap(), vec![(4, 4), (4, 4), (2, 8)]);
        assert!(parse_mesh_list("0x4").is_err());
        assert!(parse_mesh_list("12").is_err());
        assert_eq!(parse_package_list("all").unwrap().len(), 2);
        assert_eq!(parse_dram_list("all").unwrap().len(), 3);
        assert_eq!(parse_method_list("all").unwrap().len(), 4);
        assert_eq!(parse_engine_list("event,analytic").unwrap().len(), 2);
        assert!(parse_engine_list("warp-drive").is_err());
    }

    #[test]
    fn sweep_command_runs_all_formats() {
        let a = app();
        for format in ["table", "csv", "json"] {
            let m = a
                .parse(&argv(&[
                    "sweep",
                    "--models",
                    "tinyllama-1.1b",
                    "--meshes",
                    "4x4",
                    "--methods",
                    "hecaton,flat-ring",
                    "--threads",
                    "2",
                    "--format",
                    format,
                ]))
                .unwrap()
                .unwrap();
            cmd_sweep(&m).unwrap();
        }
        let bad = a
            .parse(&argv(&["sweep", "--format", "yaml"]))
            .unwrap()
            .unwrap();
        assert!(cmd_sweep(&bad).is_err());
    }

    #[test]
    fn simulate_command_runs() {
        let a = app();
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16"]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
    }

    #[test]
    fn simulate_command_runs_event_engine() {
        let a = app();
        for engine in ["event", "event-prefetch"] {
            let m = a
                .parse(&argv(&[
                    "simulate",
                    "--model",
                    "tinyllama-1.1b",
                    "--dies",
                    "16",
                    "--engine",
                    engine,
                ]))
                .unwrap()
                .unwrap();
            cmd_simulate(&m).unwrap();
        }
        let bad = a
            .parse(&argv(&["simulate", "--engine", "bogus"]))
            .unwrap()
            .unwrap();
        assert!(cmd_simulate(&bad).is_err());
    }

    #[test]
    fn info_runs() {
        cmd_info().unwrap();
    }

    #[test]
    fn cluster_list_parsers() {
        assert_eq!(parse_usize_list("1,2, 4", "dp").unwrap(), vec![1, 2, 4]);
        assert!(parse_usize_list("0", "dp").is_err());
        assert!(parse_usize_list("x", "dp").is_err());
        assert!(parse_usize_list("", "dp").is_err());
        let inter = parse_inter_list("substrate,optical,128").unwrap();
        assert_eq!(inter.len(), 3);
        assert!((inter[2].bandwidth - 128.0e9).abs() < 1.0);
        assert!(parse_inter_list("warp").is_err());
    }

    /// `simulate` with cluster knobs routes through the cluster simulator;
    /// malformed shapes error cleanly.
    #[test]
    fn simulate_cluster_flags() {
        let a = app();
        let m = a
            .parse(&argv(&[
                "simulate",
                "--model",
                "tinyllama-1.1b",
                "--dies",
                "16",
                "--n-packages",
                "4",
                "--dp",
                "2",
                "--pp",
                "2",
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
        for args in [
            // dp x pp != packages
            vec!["simulate", "--dies", "16", "--n-packages", "4", "--dp", "2", "--pp", "1"],
            // unknown fabric
            vec!["simulate", "--dies", "16", "--dp", "2", "--n-packages", "2", "--inter-bw", "x"],
            // unknown fabric is rejected even on the degenerate 1x1x1 shape
            vec!["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--inter-bw", "warp"],
            // pp deeper than the layer stack
            vec![
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--n-packages", "23",
                "--dp", "1", "--pp", "23",
            ],
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            assert!(cmd_simulate(&m).is_err(), "{args:?} should error cleanly");
        }
    }

    #[test]
    fn sweep_cluster_axes_run_all_formats() {
        let a = app();
        for format in ["table", "csv", "json"] {
            let m = a
                .parse(&argv(&[
                    "sweep",
                    "--models",
                    "tinyllama-1.1b",
                    "--meshes",
                    "4x4",
                    "--methods",
                    "hecaton",
                    "--n-packages",
                    "4",
                    "--dp",
                    "1,2,4",
                    "--pp",
                    "1,2,4",
                    "--threads",
                    "2",
                    "--format",
                    format,
                ]))
                .unwrap()
                .unwrap();
            cmd_sweep(&m).unwrap();
        }
        // A grid whose every combination is inconsistent errors out.
        let bad = a
            .parse(&argv(&[
                "sweep",
                "--models",
                "tinyllama-1.1b",
                "--meshes",
                "4x4",
                "--n-packages",
                "4",
                "--dp",
                "3",
                "--pp",
                "3",
            ]))
            .unwrap()
            .unwrap();
        assert!(cmd_sweep(&bad).is_err());
    }
}
