//! `hecaton` command-line interface.
//!
//! Subcommands:
//! * `simulate`  — run the system simulator on one (model, hardware, method)
//! * `reproduce` — regenerate a paper table/figure (fig8, fig9, …)
//! * `train`     — functional distributed training with a loss curve
//! * `info`      — show presets and the resolved configuration

use anyhow::anyhow;

use crate::config::presets::{eval_models, model_preset};
use crate::config::{DramKind, HardwareConfig, PackageKind};
use crate::nop::analytic::Method;
use crate::sim::system::{simulate_with, EngineKind, SimOptions};
use crate::util::cli::{App, CommandSpec, Matches};
use crate::util::table::Table;

/// Build the CLI application spec.
pub fn app() -> App {
    App::new("hecaton", "scalable waferscale-chiplet LLM training (paper reproduction)")
        .command(
            CommandSpec::new("simulate", "simulate one training batch")
                .opt("model", "llama2-70b", "model preset (see `hecaton info`)")
                .opt("dies", "256", "number of computing dies (square) or use --mesh")
                .opt("mesh", "", "explicit RxC mesh, e.g. 2x8")
                .opt("package", "standard", "packaging: standard | advanced")
                .opt("dram", "ddr5-6400", "dram: ddr4-3200 | ddr5-6400 | hbm2")
                .opt("method", "hecaton", "hecaton | flat-ring | torus-ring | optimus")
                .opt("engine", "analytic", "timing backend: analytic | event | event-prefetch")
                .opt("config", "", "TOML config file (overrides the above)"),
        )
        .command(
            CommandSpec::new("reproduce", "regenerate a paper table/figure")
                .pos("experiment", "fig8 | fig9 | fig10 | fig11 | table3 | table4 | gpu | weak | all"),
        )
        .command(
            CommandSpec::new("train", "functional distributed training (real numerics)")
                .opt("model", "tiny", "tiny | e2e-100m")
                .opt("mesh", "2x2", "die mesh RxC (artifacts must exist)")
                .opt("steps", "20", "training steps")
                .opt("lr", "0.5", "learning rate")
                .opt("seed", "1234", "seed")
                .opt("task", "next-token", "next-token | induction"),
        )
        .command(CommandSpec::new("info", "list presets and hardware defaults"))
}

/// Entry point used by `main.rs`.
pub fn run(args: &[String]) -> crate::Result<i32> {
    let app = app();
    let Some(m) = app.parse(args).map_err(|e| anyhow!("{e}"))? else {
        return Ok(0); // help printed
    };
    match m.command.as_str() {
        "simulate" => cmd_simulate(&m),
        "reproduce" => cmd_reproduce(&m),
        "train" => cmd_train(&m),
        "info" => cmd_info(),
        other => Err(anyhow!("unhandled command {other}")),
    }?;
    Ok(0)
}

fn parse_mesh(s: &str) -> crate::Result<(usize, usize)> {
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| anyhow!("mesh must be RxC, e.g. 4x4"))?;
    Ok((r.parse()?, c.parse()?))
}

fn cmd_simulate(m: &Matches) -> crate::Result<()> {
    let (model, hw) = if !m.value("config").is_empty() {
        let setup = crate::config::file::load(m.value("config"))?;
        (setup.model, setup.hardware)
    } else {
        let model = model_preset(m.value("model"))
            .ok_or_else(|| anyhow!("unknown model '{}'", m.value("model")))?;
        let package = PackageKind::parse(m.value("package"))
            .ok_or_else(|| anyhow!("bad package"))?;
        let dram = DramKind::parse(m.value("dram")).ok_or_else(|| anyhow!("bad dram"))?;
        let hw = if !m.value("mesh").is_empty() {
            let (r, c) = parse_mesh(m.value("mesh"))?;
            HardwareConfig::mesh(r, c, package, dram)
        } else {
            HardwareConfig::square(m.parse_value("dies")?, package, dram)
        };
        (model, hw)
    };
    let method = Method::parse(m.value("method")).ok_or_else(|| anyhow!("bad method"))?;
    let engine = EngineKind::parse(m.value("engine"))
        .ok_or_else(|| anyhow!("bad engine '{}'", m.value("engine")))?;
    let r = simulate_with(
        &model,
        &hw,
        method,
        SimOptions {
            engine,
            ..SimOptions::default()
        },
    );

    let mut t = Table::new(&["metric", "value"]).label_first();
    let lat = r.latency.raw();
    t.row(crate::table_row!["model", model.name]);
    t.row(crate::table_row![
        "mesh",
        format!("{}x{} ({} dies, {})", hw.mesh_rows, hw.mesh_cols, r.dies, hw.package.name())
    ]);
    t.row(crate::table_row!["method", method.name()]);
    t.row(crate::table_row!["engine", r.engine.name()]);
    t.row(crate::table_row!["batch latency", r.latency]);
    t.row(crate::table_row![
        "  compute",
        format!("{} ({:.1}%)", r.breakdown.compute, 100.0 * r.breakdown.compute.raw() / lat)
    ]);
    t.row(crate::table_row![
        "  NoP transmission",
        format!(
            "{} ({:.1}%)",
            r.breakdown.nop_transmission,
            100.0 * r.breakdown.nop_transmission.raw() / lat
        )
    ]);
    t.row(crate::table_row![
        "  NoP link latency",
        format!("{} ({:.2}%)", r.breakdown.nop_link, 100.0 * r.breakdown.nop_link.raw() / lat)
    ]);
    t.row(crate::table_row![
        "  exposed DRAM",
        format!("{} ({:.1}%)", r.breakdown.dram_exposed, 100.0 * r.breakdown.dram_exposed.raw() / lat)
    ]);
    t.row(crate::table_row!["energy / batch", r.energy_total]);
    t.row(crate::table_row![
        "throughput",
        format!("{:.0} tokens/s", r.tokens_per_sec(&model))
    ]);
    t.row(crate::table_row![
        "achieved compute",
        crate::util::fmt::flops(r.achieved_flops())
    ]);
    t.row(crate::table_row![
        "efficiency",
        format!("{} /W", crate::util::fmt::flops(r.flops_per_watt()))
    ]);
    t.row(crate::table_row![
        "PE utilization (worst block)",
        format!("{:.1}%", 100.0 * r.min_utilization)
    ]);
    t.row(crate::table_row![
        "mini-batch",
        format!("{} tokens x {}", r.minibatch_tokens, r.n_minibatches)
    ]);
    t.row(crate::table_row![
        "SRAM act/weight peak",
        format!("{} / {}", r.sram.act_peak, r.sram.weight_peak)
    ]);
    t.row(crate::table_row![
        "feasible",
        if r.feasible() { "yes" } else { "NO (SRAM overflow or layout)" }
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_reproduce(m: &Matches) -> crate::Result<()> {
    let exp = m.pos(0).ok_or_else(|| anyhow!("which experiment? (fig8|...|all)"))?;
    if exp == "all" {
        for id in crate::report::experiments() {
            println!("{}", crate::report::run(id)?);
        }
    } else {
        println!("{}", crate::report::run(exp)?);
    }
    Ok(())
}

fn cmd_train(m: &Matches) -> crate::Result<()> {
    use crate::coordinator::{coord_model, Coordinator, MeshCfg};
    use crate::train::data::Corpus;

    let model = coord_model(m.value("model"))
        .ok_or_else(|| anyhow!("model '{}' has no functional preset", m.value("model")))?;
    let (rows, cols) = parse_mesh(m.value("mesh"))?;
    let tokens = match model.name.as_str() {
        "tiny" => 64,
        _ => model.seq_len,
    };
    let seed: u64 = m.parse_value("seed")?;
    let mut corpus = match m.value("task") {
        "induction" => Corpus::induction(model.vocab, model.seq_len, seed),
        _ => Corpus::next_token(model.vocab, model.seq_len, seed),
    };
    let cfg = MeshCfg::new(model, rows, cols, tokens);
    println!(
        "spawning {}x{} die mesh for '{}' ({} tokens/mini-batch)…",
        rows, cols, cfg.model.name, tokens
    );
    let mut coord = Coordinator::new(cfg, seed)?;
    let logs = crate::train::train(
        &mut coord,
        &mut corpus,
        crate::train::TrainCfg {
            steps: m.parse_value("steps")?,
            lr: m.parse_value("lr")?,
            seed,
        },
    )?;
    let mut t = Table::new(&["step", "loss", "wall"]).label_first();
    for l in &logs {
        t.row(crate::table_row![l.step, format!("{:.4}", l.loss), format!("{:?}", l.wall)]);
    }
    println!("{}", t.render());
    coord.shutdown()?;
    Ok(())
}

fn cmd_info() -> crate::Result<()> {
    let mut t = Table::new(&["model", "hidden", "layers", "heads", "seq", "params"])
        .with_title("Model presets")
        .label_first();
    for name in eval_models() {
        let m = model_preset(name).unwrap();
        t.row(crate::table_row![
            m.name,
            m.hidden,
            m.layers,
            m.heads,
            m.seq_len,
            crate::util::fmt::count(m.total_params())
        ]);
    }
    println!("{}", t.render());
    let die = HardwareConfig::paper_die();
    println!(
        "Die: {} MACs/cycle @ {:.0} MHz = {} peak; {} + {} buffers; {} mm2",
        die.macs_per_cycle(),
        die.freq_hz / 1e6,
        crate::util::fmt::flops(die.peak_flops()),
        die.weight_buf,
        die.act_buf,
        die.area_mm2
    );
    println!("Functional (train) presets: tiny, e2e-100m — see aot.py DEPLOYMENTS");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn app_parses_all_subcommands() {
        let a = app();
        assert!(a.parse(&argv(&["simulate", "--model", "tiny"])).unwrap().is_some());
        assert!(a.parse(&argv(&["reproduce", "fig8"])).unwrap().is_some());
        assert!(a.parse(&argv(&["train", "--steps", "3"])).unwrap().is_some());
        assert!(a.parse(&argv(&["info"])).unwrap().is_some());
        assert!(a.parse(&argv(&["bogus"])).is_err());
    }

    #[test]
    fn parse_mesh_forms() {
        assert_eq!(parse_mesh("4x4").unwrap(), (4, 4));
        assert_eq!(parse_mesh("2x8").unwrap(), (2, 8));
        assert!(parse_mesh("44").is_err());
    }

    #[test]
    fn simulate_command_runs() {
        let a = app();
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16"]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
    }

    #[test]
    fn simulate_command_runs_event_engine() {
        let a = app();
        for engine in ["event", "event-prefetch"] {
            let m = a
                .parse(&argv(&[
                    "simulate",
                    "--model",
                    "tinyllama-1.1b",
                    "--dies",
                    "16",
                    "--engine",
                    engine,
                ]))
                .unwrap()
                .unwrap();
            cmd_simulate(&m).unwrap();
        }
        let bad = a
            .parse(&argv(&["simulate", "--engine", "bogus"]))
            .unwrap()
            .unwrap();
        assert!(cmd_simulate(&bad).is_err());
    }

    #[test]
    fn info_runs() {
        cmd_info().unwrap();
    }
}
