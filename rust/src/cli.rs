//! `hecaton` command-line interface.
//!
//! Subcommands:
//! * `simulate`  — run one scenario built from flags (or a config file)
//! * `sweep`     — run a scenario grid in parallel (memoized planning,
//!   Pareto-annotated table/CSV/JSON output)
//! * `search`    — branch-and-bound search over the same grid axes:
//!   same optimum/Pareto front as an exhaustive sweep, a fraction of the
//!   evaluations ([`crate::search`])
//! * `run`       — execute a scenario TOML file (single scenario, a
//!   `[sweep]` grid, or a `[search]` over one) — see `examples/scenarios/`
//! * `reproduce` — regenerate a paper table/figure (fig8, fig9, …)
//! * `train`     — functional distributed training with a loss curve
//! * `info`      — show presets and the resolved configuration
//!   (`--format json` for machine-readable presets)
//! * `lint`      — determinism lint over the crate's own sources
//!   ([`crate::lint`]; non-zero exit on findings)
//! * `audit`     — static verification of the simulator's invariant
//!   contracts over scenario files ([`crate::audit`])
//!
//! Every evaluation path funnels into [`crate::scenario`]: the flags are
//! parsed once by [`ScenarioArgs`] into a [`Scenario`] or a
//! [`ScenarioGrid`], and `scenario::evaluate`/`scenario::run_on` do the
//! rest — `simulate`, `sweep` and `run` share one flag→scenario pipeline
//! instead of three copies of it.

use anyhow::anyhow;

use crate::config::cluster::{cluster_preset, cluster_presets, ClusterConfig};
use crate::config::file::LoadedScenario;
use crate::config::presets::{all_model_presets, eval_models, model_preset};
use crate::config::{DramKind, HardwareConfig, ModelConfig, PackageKind, TopologyKind};
use crate::memory::sram::OccupancyReport;
use crate::nop::analytic::Method;
use crate::scenario::{self, axis, EvalDetail, Scenario, ScenarioGrid};
use crate::sim::cluster::{ClusterPlan, ClusterResult};
use crate::sim::sweep::PlanCache;
use crate::sim::system::{EngineKind, SimResult};
use crate::util::cli::{split_list, unknown_value, App, CommandSpec, Matches};
use crate::util::fmt::pct;
use crate::util::table::Table;

/// Build the CLI application spec.
pub fn app() -> App {
    App::new("hecaton", "scalable waferscale-chiplet LLM training (paper reproduction)")
        .command(
            CommandSpec::new("simulate", "simulate one training batch")
                .opt("model", "llama2-70b", "model preset (see `hecaton info`)")
                .opt("dies", "256", "number of computing dies (square) or use --mesh")
                .opt("mesh", "", "explicit RxC mesh, e.g. 2x8")
                .opt("package", "standard", "packaging: standard | advanced")
                .opt("dram", "ddr5-6400", "dram: ddr4-3200 | ddr5-6400 | hbm2")
                .opt("topo", "mesh", "intra-package NoP topology: mesh | torus")
                .opt("method", "hecaton", "hecaton | flat-ring | torus-ring | optimus")
                .opt("engine", "analytic", "timing backend: analytic | event | event-prefetch | packet")
                .opt("checkpoint", "none", "activation checkpointing: none | auto | every-<k>")
                .opt("sram-mib", "none", "enforced per-die SRAM capacity in MiB (none = report only)")
                .opt("n-packages", "1", "packages in the cluster (must equal dp x pp)")
                .opt("dp", "1", "data-parallel replicas across packages")
                .opt("pp", "1", "pipeline stages across packages (1F1B)")
                .opt("inter-bw", "substrate", "inter-package fabric: substrate | optical | fat-tree | <GB/s>")
                .opt("trace", "", "with --engine packet on a cluster: write per-queue occupancy JSONL here")
                .opt("config", "", "TOML config file (overrides the above)"),
        )
        .command(
            CommandSpec::new("sweep", "run a scenario grid in parallel (plan cache + Pareto)")
                .opt("models", "tinyllama-1.1b", "comma list of model presets, or 'all'")
                .opt("meshes", "4x4", "comma list of RxC meshes and/or square die counts, e.g. 4x4,2x8,64")
                .opt("packages", "standard", "comma list: standard,advanced or 'all'")
                .opt("drams", "ddr5-6400", "comma list: ddr4-3200,ddr5-6400,hbm2 or 'all'")
                .opt("topos", "mesh", "comma list of NoP topologies: mesh,torus or 'all'")
                .opt("methods", "all", "comma list of TP methods, or 'all'")
                .opt("engines", "analytic", "comma list of timing backends (analytic,event,event-prefetch,packet), or 'all'")
                .opt("checkpoint", "none", "comma list of checkpoint policies: none | auto | every-<k>")
                .opt("sram-mib", "none", "comma list of enforced per-die SRAM capacities (MiB or 'none')")
                .opt("n-packages", "1", "comma list of cluster package counts (dp x pp)")
                .opt("dp", "1", "comma list of data-parallel widths")
                .opt("pp", "1", "comma list of pipeline depths")
                .opt("inter-bw", "substrate", "comma list of fabrics: substrate | optical | fat-tree | <GB/s>")
                .opt("threads", "0", "worker threads (0 = one per core; 1 = serial)")
                .opt("format", "table", "output format: table | csv | json"),
        )
        .command(
            CommandSpec::new("search", "pruned branch-and-bound search over a scenario grid")
                .opt("objective", "latency", "latency | energy | pareto | latency-under-sram")
                .opt("budget-sram-mib", "", "per-die SRAM budget in MiB (latency-under-sram only)")
                .opt("models", "tinyllama-1.1b", "comma list of model presets, or 'all'")
                .opt("meshes", "4x4", "comma list of RxC meshes and/or square die counts, e.g. 4x4,2x8,64")
                .opt("packages", "standard", "comma list: standard,advanced or 'all'")
                .opt("drams", "ddr5-6400", "comma list: ddr4-3200,ddr5-6400,hbm2 or 'all'")
                .opt("topos", "mesh", "comma list of NoP topologies: mesh,torus or 'all'")
                .opt("methods", "all", "comma list of TP methods, or 'all'")
                .opt("engines", "analytic", "comma list of timing backends (analytic,event,event-prefetch,packet), or 'all'")
                .opt("checkpoint", "none", "comma list of checkpoint policies: none | auto | every-<k>")
                .opt("sram-mib", "none", "comma list of enforced per-die SRAM capacities (MiB or 'none')")
                .opt("n-packages", "1", "comma list of cluster package counts (dp x pp)")
                .opt("dp", "1", "comma list of data-parallel widths")
                .opt("pp", "1", "comma list of pipeline depths")
                .opt("inter-bw", "substrate", "comma list of fabrics: substrate | optical | fat-tree | <GB/s>")
                .opt("batch", "32", "frontier batch width in plan groups (thread-independent)")
                .opt("threads", "0", "worker threads (0 = one per core; results are thread-independent)")
                .opt("format", "table", "output format: table | csv | json"),
        )
        .command(
            CommandSpec::new("run", "execute a scenario TOML file (single scenario or [sweep] grid)")
                .pos("scenario", "path to a scenario file (see examples/scenarios/)")
                .opt("threads", "", "override the file's [options] threads")
                .opt("format", "", "override the file's [options] format: table | csv | json"),
        )
        .command(
            CommandSpec::new("reproduce", "regenerate a paper table/figure")
                .pos("experiment", "fig8 | fig9 | fig10 | fig11 | table3 | table4 | gpu | weak | cluster | sram | search | all"),
        )
        .command(
            CommandSpec::new("train", "functional distributed training (real numerics)")
                .opt("model", "tiny", "tiny | e2e-100m")
                .opt("mesh", "2x2", "die mesh RxC (artifacts must exist)")
                .opt("steps", "20", "training steps")
                .opt("lr", "0.5", "learning rate")
                .opt("seed", "1234", "seed")
                .opt("task", "next-token", "next-token | induction"),
        )
        .command(
            CommandSpec::new("info", "list presets and hardware defaults")
                .opt("format", "table", "output format: table | json"),
        )
        .command(
            CommandSpec::new("lint", "determinism lint over the crate's own sources")
                .pos("path", "source root to lint (default: this crate's src/)")
                .opt("rules", "all", "comma list of rules to report, or 'all' (see `hecaton info`)"),
        )
        .command(
            CommandSpec::new("audit", "statically verify the simulator's invariant contracts")
                .pos("scenario", "scenario TOML file to audit (omit with --all-examples)")
                .opt("checks", "all", "comma list of checks to report, or 'all' (see `hecaton info`)")
                .opt("examples-dir", "", "scenario directory for --all-examples (default: examples/scenarios/)")
                .flag("all-examples", "audit every *.toml in the examples directory"),
        )
        .command(
            CommandSpec::new("bench", "run the perf suites against the committed baseline")
                .opt("suite", "all", "bench suite: hotpath | sweep | all")
                .opt("baseline-dir", "", "directory holding BENCH_*.json (default: repo root)")
                .opt("threshold", "0.20", "median regression ratio that fails --compare (0.20 = 20%)")
                .opt("save", "", "also write the refreshed JSON files into this directory")
                .flag("compare", "exit non-zero when any bench regresses past --threshold")
                .flag("json", "with --compare: emit the diff as a JSON array on stdout")
                .flag("update", "rewrite the baseline files in place with this run's results")
                .flag("quick", "short measurement window (CI/smoke; noisier medians)"),
        )
}

/// Entry point used by `main.rs`.
pub fn run(args: &[String]) -> crate::Result<i32> {
    let app = app();
    let Some(m) = app.parse(args).map_err(|e| anyhow!("{e}"))? else {
        return Ok(0); // help printed
    };
    match m.command.as_str() {
        "simulate" => cmd_simulate(&m),
        "sweep" => cmd_sweep(&m),
        "search" => cmd_search(&m),
        "run" => cmd_run(&m),
        "reproduce" => cmd_reproduce(&m),
        "train" => cmd_train(&m),
        "info" => cmd_info(&m),
        "lint" => cmd_lint(&m),
        "audit" => cmd_audit(&m),
        "bench" => cmd_bench(&m),
        other => Err(anyhow!("unhandled command {other}")),
    }?;
    Ok(0)
}

// ───────────────────────── shared flag → scenario parsing ─────────────────────────

/// The one shared flag→scenario parser: `simulate` reads each axis as a
/// single value, `sweep` as a comma list — both through
/// [`crate::scenario::axis`], so spellings, case-insensitivity and
/// "did you mean" suggestions are identical across subcommands (and match
/// the TOML loader, which uses the same parsers).
struct ScenarioArgs;

impl ScenarioArgs {
    /// `sweep` flags (comma lists) → a scenario grid.
    fn sweep_grid(m: &Matches) -> crate::Result<ScenarioGrid> {
        Ok(ScenarioGrid {
            models: axis::models(&split_list(m.value("models")))?,
            meshes: axis::meshes(&split_list(m.value("meshes")))?,
            packages: axis::package_kinds(&split_list(m.value("packages")))?,
            drams: axis::drams(&split_list(m.value("drams")))?,
            sram: axis::sram_limits(&split_list(m.value("sram-mib")))?,
            topos: axis::topos(&split_list(m.value("topos")))?,
            methods: axis::methods(&split_list(m.value("methods")))?,
            engines: axis::engines(&split_list(m.value("engines")))?,
            checkpoints: axis::checkpoints(&split_list(m.value("checkpoint")))?,
            n_packages: axis::counts(&split_list(m.value("n-packages")), "n-packages")?,
            dp: axis::counts(&split_list(m.value("dp")), "dp")?,
            pp: axis::counts(&split_list(m.value("pp")), "pp")?,
            inter: axis::inters(&split_list(m.value("inter-bw")))?,
        })
    }

    /// `simulate` flags (single values, plus `--config`) → one scenario.
    ///
    /// The cluster knobs (`--n-packages`, matching the sweep axis;
    /// `--package` remains the packaging *kind*) route anything beyond
    /// the degenerate 1×1×1 shape through the cluster simulator; the
    /// defaults keep the established single-package path (and its output)
    /// untouched. The fabric spec is validated even when unused, so a
    /// typo never passes silently.
    fn simulate_scenario(m: &Matches) -> crate::Result<Scenario> {
        let builder = if !m.value("config").is_empty() {
            let setup = crate::config::file::load(m.value("config"))?;
            Scenario::builder(setup.model).hardware(setup.hardware)
        } else {
            let model = model_preset(m.value("model")).ok_or_else(|| {
                anyhow!("{}", unknown_value("model", m.value("model"), all_model_presets()))
            })?;
            let package = PackageKind::parse(m.value("package")).ok_or_else(|| {
                anyhow!(
                    "{}",
                    unknown_value("package", m.value("package"), &["standard", "advanced"])
                )
            })?;
            let dram = DramKind::parse(m.value("dram")).ok_or_else(|| {
                anyhow!(
                    "{}",
                    unknown_value(
                        "dram",
                        m.value("dram"),
                        &["ddr4-3200", "ddr5-6400", "hbm2"]
                    )
                )
            })?;
            let b = Scenario::builder(model).package(package).dram(dram);
            if !m.value("mesh").is_empty() {
                let (rows, cols) = axis::mesh(m.value("mesh"))?;
                b.mesh(rows, cols)
            } else {
                b.dies(m.parse_value("dies")?)
            }
        };
        let method_names: Vec<&str> = Method::all().iter().map(|x| x.name()).collect();
        let method = Method::parse(m.value("method")).ok_or_else(|| {
            anyhow!("{}", unknown_value("method", m.value("method"), &method_names))
        })?;
        let engine_names: Vec<&str> = EngineKind::all().iter().map(|x| x.name()).collect();
        let engine = EngineKind::parse(m.value("engine")).ok_or_else(|| {
            anyhow!("{}", unknown_value("engine", m.value("engine"), &engine_names))
        })?;
        let inter = axis::inters(&[m.value("inter-bw")])?.remove(0);
        let checkpoint = axis::checkpoints(&[m.value("checkpoint")])?.remove(0);
        let sram = axis::sram_limits(&[m.value("sram-mib")])?.remove(0);
        let topo = axis::topos(&[m.value("topo")])?.remove(0);
        let mut builder = builder
            .method(method)
            .engine(engine)
            .checkpoint(checkpoint)
            .topology(topo);
        if let Some(cap) = sram {
            builder = builder.sram_limit(cap);
        }
        builder
            .cluster(m.parse_value("n-packages")?, m.parse_value("dp")?, m.parse_value("pp")?)
            .inter(inter)
            .build()
    }
}

// ───────────────────────── simulate / run ─────────────────────────

fn cmd_simulate(m: &Matches) -> crate::Result<()> {
    let scenario = ScenarioArgs::simulate_scenario(m)?;
    print_scenario_evaluation(&scenario)?;
    if !m.value("trace").is_empty() {
        write_packet_trace(&scenario, m.value("trace"))?;
    }
    Ok(())
}

/// `--trace <path>`: export the packet engine's per-queue occupancy
/// samples as JSONL (one `{"t":…,"queue":…,"pkts":…,"dropped":…}` object
/// per line). Only meaningful when the packet backend actually runs
/// shared-fabric flows — a cluster target under `--engine packet` — so
/// anything else errors rather than writing a silently empty file.
fn write_packet_trace(scenario: &Scenario, path: &str) -> crate::Result<()> {
    if scenario.engine != EngineKind::Packet {
        return Err(anyhow!(
            "--trace requires --engine packet (got --engine {})",
            scenario.engine.name()
        ));
    }
    let Some(c) = scenario.cluster_config() else {
        return Err(anyhow!(
            "--trace requires a cluster target (--n-packages/--dp/--pp): the packet \
             engine's queues live on the inter-package fabric"
        ));
    };
    let plan =
        ClusterPlan::build(&scenario.model, c, scenario.method, scenario.opts, &PlanCache::new())?;
    let trace = plan.packet_trace();
    std::fs::write(path, trace.to_jsonl())
        .map_err(|e| anyhow!("writing packet trace to {path}: {e}"))?;
    println!(
        "packet trace: {} samples over {} queues -> {path}{}",
        trace.samples.len(),
        trace.queues.len(),
        if trace.truncated { " (truncated at sample cap)" } else { "" }
    );
    Ok(())
}

/// Evaluate one scenario and print the matching table (package breakdown
/// or cluster breakdown) — shared by `simulate` and `run`.
fn print_scenario_evaluation(scenario: &Scenario) -> crate::Result<()> {
    let eval = scenario.evaluate()?;
    match &eval.detail {
        EvalDetail::Package(r) => print_package_simulation(&scenario.model, scenario.hw(), r),
        EvalDetail::Cluster(r) => print_cluster_simulation(
            &scenario.model,
            scenario.cluster_config().expect("cluster evaluations come from cluster targets"),
            r,
        ),
    }
}

/// Single-package result table (the classic `simulate` output).
fn print_package_simulation(
    model: &ModelConfig,
    hw: &HardwareConfig,
    r: &SimResult,
) -> crate::Result<()> {
    let mut t = Table::new(&["metric", "value"]).label_first();
    let lat = r.latency.raw();
    t.row(crate::table_row!["model", model.name.clone()]);
    t.row(crate::table_row![
        "mesh",
        format!("{}x{} ({} dies, {})", hw.mesh_rows, hw.mesh_cols, r.dies, hw.package.name())
    ]);
    t.row(crate::table_row!["method", r.method.name()]);
    t.row(crate::table_row!["engine", r.engine.name()]);
    t.row(crate::table_row!["batch latency", r.latency]);
    t.row(crate::table_row![
        "  compute",
        format!("{} ({})", r.breakdown.compute, pct(r.breakdown.compute.raw(), lat, 1))
    ]);
    t.row(crate::table_row![
        "  NoP transmission",
        format!(
            "{} ({})",
            r.breakdown.nop_transmission,
            pct(r.breakdown.nop_transmission.raw(), lat, 1)
        )
    ]);
    t.row(crate::table_row![
        "  NoP link latency",
        format!("{} ({})", r.breakdown.nop_link, pct(r.breakdown.nop_link.raw(), lat, 2))
    ]);
    t.row(crate::table_row![
        "  exposed DRAM",
        format!("{} ({})", r.breakdown.dram_exposed, pct(r.breakdown.dram_exposed.raw(), lat, 1))
    ]);
    t.row(crate::table_row!["energy / batch", r.energy_total]);
    t.row(crate::table_row![
        "throughput",
        format!("{:.0} tokens/s", r.tokens_per_sec(model))
    ]);
    t.row(crate::table_row![
        "achieved compute",
        crate::util::fmt::flops(r.achieved_flops())
    ]);
    t.row(crate::table_row![
        "efficiency",
        format!("{} /W", crate::util::fmt::flops(r.flops_per_watt()))
    ]);
    t.row(crate::table_row![
        "PE utilization (worst block)",
        match r.min_utilization {
            Some(u) => format!("{:.1}%", 100.0 * u),
            None => "—".to_string(),
        }
    ]);
    t.row(crate::table_row![
        "mini-batch",
        format!("{} tokens x {}", r.minibatch_tokens, r.n_minibatches)
    ]);
    t.row(crate::table_row![
        "SRAM act/weight peak",
        format!("{} / {}", r.sram.act_peak, r.sram.weight_peak)
    ]);
    t.row(crate::table_row!["checkpoint", r.checkpoint.label()]);
    t.row(crate::table_row![
        "SRAM occupancy peak",
        occupancy_cell(&r.occupancy)
    ]);
    t.row(crate::table_row![
        "feasible",
        if r.feasible() { "yes" } else { "NO (SRAM overflow or layout)" }
    ]);
    println!("{}", t.render());
    Ok(())
}

/// Render an occupancy summary cell: peak vs per-die capacity, flagging
/// overflow (enforced overflows error before reaching a table).
fn occupancy_cell(o: &OccupancyReport) -> String {
    format!(
        "{} / {} per die{}",
        o.peak,
        o.capacity,
        if o.fits() { "" } else { " (OVERFLOW)" }
    )
}

/// Cluster result table: one cluster batch with the hybrid-parallelism
/// breakdown.
fn print_cluster_simulation(
    model: &ModelConfig,
    cluster: &ClusterConfig,
    r: &ClusterResult,
) -> crate::Result<()> {
    let lat = r.latency.raw();
    let hw = &cluster.package_hw;
    let mut t = Table::new(&["metric", "value"]).label_first();
    t.row(crate::table_row!["model", model.name.clone()]);
    t.row(crate::table_row![
        "cluster",
        format!(
            "{} packages (dp={} x pp={}), {} dies total",
            r.packages, r.dp, r.pp, r.total_dies
        )
    ]);
    t.row(crate::table_row![
        "package",
        format!("{}x{} dies, {}", hw.mesh_rows, hw.mesh_cols, hw.package.name())
    ]);
    t.row(crate::table_row![
        "fabric",
        format!("{:.0} GB/s, {}", cluster.inter.gbs(), cluster.inter.latency)
    ]);
    t.row(crate::table_row!["method (in-package TP)", r.method.name()]);
    t.row(crate::table_row!["engine", r.engine.name()]);
    t.row(crate::table_row!["batch latency", r.latency]);
    t.row(crate::table_row![
        "  pipeline bubble",
        format!("{} ({})", r.bubble, pct(r.bubble.raw(), lat, 1))
    ]);
    t.row(crate::table_row![
        "  stage p2p fill",
        format!("{} ({})", r.p2p, pct(r.p2p.raw(), lat, 2))
    ]);
    t.row(crate::table_row![
        "  grad all-reduce",
        format!("{} ({})", r.grad_allreduce, pct(r.grad_allreduce.raw(), lat, 1))
    ]);
    t.row(crate::table_row!["stage latency", r.stage.latency]);
    t.row(crate::table_row!["1F1B microbatches", r.microbatches]);
    t.row(crate::table_row!["checkpoint", r.stage.checkpoint.label()]);
    t.row(crate::table_row![
        "SRAM occupancy peak",
        occupancy_cell(&r.occupancy)
    ]);
    t.row(crate::table_row!["energy / batch", r.energy_total]);
    t.row(crate::table_row![
        "throughput",
        format!("{:.0} tokens/s", r.tokens_per_sec())
    ]);
    t.row(crate::table_row![
        "feasible",
        if r.feasible() { "yes" } else { "NO (SRAM overflow or layout)" }
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_run(m: &Matches) -> crate::Result<()> {
    let path = m
        .pos(0)
        .ok_or_else(|| anyhow!("which scenario file? (see examples/scenarios/)"))?;
    match crate::config::file::load_scenario(path)? {
        LoadedScenario::One(scenario) => {
            // The grid-only overrides must not be silently ignored.
            for flag in ["threads", "format"] {
                if !m.value(flag).is_empty() {
                    return Err(anyhow!(
                        "--{flag} only applies to [sweep] grid files; \
                         {path} holds a single scenario"
                    ));
                }
            }
            print_scenario_evaluation(&scenario)
        }
        LoadedScenario::Grid {
            grid,
            threads,
            format,
            search,
        } => {
            let threads = if m.value("threads").is_empty() {
                threads
            } else {
                m.parse_value("threads")?
            };
            let format = if m.value("format").is_empty() {
                format
            } else {
                let f = m.value("format");
                if !matches!(f, "table" | "csv" | "json") {
                    return Err(anyhow!("bad format '{f}' (table | csv | json)"));
                }
                f.to_string()
            };
            match search {
                Some(spec) => run_search(&grid, &spec.config(threads), &format),
                None => run_grid(&grid, threads, &format),
            }
        }
    }
}

// ───────────────────────── sweep ─────────────────────────

fn cmd_sweep(m: &Matches) -> crate::Result<()> {
    // Validate the output format *before* burning cores on the grid.
    let format = m.value("format");
    if !matches!(format, "table" | "csv" | "json") {
        return Err(anyhow!("bad format '{format}' (table | csv | json)"));
    }
    let threads: usize = m.parse_value("threads")?;
    let grid = ScenarioArgs::sweep_grid(m)?;
    run_grid(&grid, threads, format)
}

/// Execute a scenario grid and render it — shared by `sweep` and `run`.
fn run_grid(grid: &ScenarioGrid, threads: usize, format: &str) -> crate::Result<()> {
    if grid.is_empty() {
        return Err(anyhow!("empty sweep grid"));
    }
    let (points, skipped) = grid.points()?;
    if points.is_empty() {
        return Err(anyhow!(
            "cluster sweep grid is empty ({skipped} combinations skipped: \
             dp x pp must equal n-packages, dp must divide the batch, pp <= layers)"
        ));
    }
    let t0 = std::time::Instant::now();
    let cache = PlanCache::new();
    let results = scenario::run_on(&cache, &points, threads)?;
    let wall = t0.elapsed();
    let front = scenario::pareto(&results);
    match format {
        "table" => println!("{}", scenario::render_table(&points, &results, &front)),
        "csv" => print!("{}", scenario::render_csv(&points, &results, &front)),
        "json" => print!("{}", scenario::render_json(&points, &results, &front)),
        _ => unreachable!("format validated above"),
    }
    // Run stats go to stderr so stdout stays machine-parseable. Both grid
    // kinds report the skip-invalid count — points must never vanish
    // silently from the expansion (the search's pruning ledger relies on
    // the same count).
    eprintln!(
        "{}: {} points ({} combinations skipped), {} plans built, {} cache hits, {:?} wall",
        if grid.is_cluster() { "cluster sweep" } else { "sweep" },
        points.len(),
        skipped,
        cache.misses(),
        cache.hits(),
        wall
    );
    Ok(())
}

// ───────────────────────── search ─────────────────────────

fn cmd_search(m: &Matches) -> crate::Result<()> {
    let format = m.value("format");
    if !matches!(format, "table" | "csv" | "json") {
        return Err(anyhow!("bad format '{format}' (table | csv | json)"));
    }
    let budget = match m.value("budget-sram-mib") {
        "" => None,
        v => {
            let mib: f64 = v
                .parse()
                .map_err(|e| anyhow!("bad budget-sram-mib '{v}': {e} (MiB per die)"))?;
            Some(crate::util::Bytes::mib(mib))
        }
    };
    let objective = crate::search::Objective::parse(m.value("objective"), budget)?;
    let batch: usize = m.parse_value("batch")?;
    if batch == 0 {
        return Err(anyhow!("--batch must be >= 1 plan group"));
    }
    let cfg = crate::search::SearchConfig {
        objective,
        threads: m.parse_value("threads")?,
        batch,
    };
    let grid = ScenarioArgs::sweep_grid(m)?;
    run_search(&grid, &cfg, format)
}

/// Execute a pruned search and render it — shared by `search` and `run`
/// (scenario files with a `[search]` section).
fn run_search(
    grid: &ScenarioGrid,
    cfg: &crate::search::SearchConfig,
    format: &str,
) -> crate::Result<()> {
    let t0 = std::time::Instant::now();
    let cache = PlanCache::new();
    let out = crate::search::run(grid, cfg, &cache)?;
    let wall = t0.elapsed();
    print!("{}", crate::search::render(&out, format)?);
    // The deterministic ledger is part of the table output; the stderr
    // line carries it for csv/json plus the run-dependent cache stats.
    eprintln!(
        "{} | {} plans built, {} cache hits, {:?} wall",
        out.counts_line(),
        out.plans_built,
        out.cache_hits,
        wall
    );
    Ok(())
}

// ───────────────────────── reproduce / train / info ─────────────────────────

fn cmd_reproduce(m: &Matches) -> crate::Result<()> {
    let exp = m.pos(0).ok_or_else(|| anyhow!("which experiment? (fig8|...|all)"))?;
    if exp == "all" {
        for id in crate::report::experiments() {
            println!("{}", crate::report::run(id)?);
        }
    } else {
        println!("{}", crate::report::run(exp)?);
    }
    Ok(())
}

fn cmd_train(m: &Matches) -> crate::Result<()> {
    use crate::coordinator::{coord_model, Coordinator, MeshCfg};
    use crate::train::data::Corpus;

    let model = coord_model(m.value("model"))
        .ok_or_else(|| anyhow!("model '{}' has no functional preset", m.value("model")))?;
    let (rows, cols) = axis::mesh(m.value("mesh"))?;
    let tokens = match model.name.as_str() {
        "tiny" => 64,
        _ => model.seq_len,
    };
    let seed: u64 = m.parse_value("seed")?;
    let mut corpus = match m.value("task") {
        "induction" => Corpus::induction(model.vocab, model.seq_len, seed),
        _ => Corpus::next_token(model.vocab, model.seq_len, seed),
    };
    let cfg = MeshCfg::new(model, rows, cols, tokens);
    println!(
        "spawning {}x{} die mesh for '{}' ({} tokens/mini-batch)…",
        rows, cols, cfg.model.name, tokens
    );
    let mut coord = Coordinator::new(cfg, seed)?;
    let logs = crate::train::train(
        &mut coord,
        &mut corpus,
        crate::train::TrainCfg {
            steps: m.parse_value("steps")?,
            lr: m.parse_value("lr")?,
            seed,
        },
    )?;
    let mut t = Table::new(&["step", "loss", "wall"]).label_first();
    for l in &logs {
        t.row(crate::table_row![l.step, format!("{:.4}", l.loss), format!("{:?}", l.wall)]);
    }
    println!("{}", t.render());
    coord.shutdown()?;
    Ok(())
}

fn cmd_info(m: &Matches) -> crate::Result<()> {
    match m.value("format") {
        "table" => print_info_table(),
        "json" => {
            println!("{}", info_json());
            Ok(())
        }
        other => Err(anyhow!("bad format '{other}' (table | json)")),
    }
}

// ───────────────────────── bench ─────────────────────────

fn cmd_bench(m: &Matches) -> crate::Result<()> {
    use crate::bench;
    use std::path::PathBuf;

    let opts = bench::BenchOpts {
        quick: m.flag("quick"),
    };
    let threshold: f64 = m.parse_value("threshold").map_err(|e| anyhow!("{e}"))?;
    if !(threshold.is_finite() && threshold > 0.0) {
        return Err(anyhow!("--threshold must be a positive ratio (e.g. 0.20)"));
    }
    let suites: Vec<&str> = match m.value("suite") {
        "all" => bench::SUITES.to_vec(),
        one => vec![one], // validated by run_suite
    };
    let base_dir = match m.value("baseline-dir") {
        "" => bench::default_baseline_dir(),
        d => PathBuf::from(d),
    };
    // --json is a machine-readable *diff*, so it only means something
    // under --compare; with it, stdout carries exactly one JSON array and
    // the advisory messages move to stderr.
    let json_diff = m.flag("json");
    if json_diff && !m.flag("compare") {
        return Err(anyhow!("--json is the machine-readable --compare diff; add --compare"));
    }

    let mut regressions: Vec<String> = Vec::new();
    let mut diff_rows: Vec<String> = Vec::new();
    for suite in suites {
        let rows = bench::run_suite(suite, opts)?;
        let path = bench::baseline_path(&base_dir, suite);
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                let baseline = bench::parse_rows(&text)
                    .map_err(|e| anyhow!("bad baseline {}: {e}", path.display()))?;
                if baseline.is_empty() {
                    let msg = format!(
                        "(baseline {} is empty — bootstrap it with `hecaton bench --update`)",
                        path.display()
                    );
                    if json_diff {
                        eprintln!("{msg}");
                    } else {
                        println!("{msg}");
                    }
                } else {
                    let mut t = Table::new(&["bench", "baseline", "now", "ratio"])
                        .with_title(&format!("{suite} vs {}", path.display()))
                        .label_first();
                    for d in bench::compare(&baseline, &rows) {
                        t.row(crate::table_row![
                            d.name,
                            crate::util::fmt::seconds(d.base_median),
                            crate::util::fmt::seconds(d.new_median),
                            format!("{:.2}x", d.ratio())
                        ]);
                        diff_rows.push(format!(
                            "  {{\"suite\": \"{suite}\", \"name\": \"{}\", \
                             \"base_median_s\": {:e}, \"new_median_s\": {:e}, \
                             \"ratio\": {:.6}, \"regressed\": {}}}",
                            d.name,
                            d.base_median,
                            d.new_median,
                            d.ratio(),
                            d.regressed(threshold)
                        ));
                        if d.regressed(threshold) {
                            regressions.push(format!(
                                "{} regressed {:.2}x (median {} -> {}, threshold {:.0}%)",
                                d.name,
                                d.ratio(),
                                crate::util::fmt::seconds(d.base_median),
                                crate::util::fmt::seconds(d.new_median),
                                threshold * 100.0
                            ));
                        }
                    }
                    if !json_diff {
                        println!("{}", t.render());
                    }
                }
            }
            Err(_) => {
                let msg = format!(
                    "(no baseline at {} — create one with `hecaton bench --update`)",
                    path.display()
                );
                if json_diff {
                    eprintln!("{msg}");
                } else {
                    println!("{msg}");
                }
            }
        }
        if m.flag("update") {
            std::fs::write(&path, bench::rows_to_json(&rows))?;
            if json_diff {
                eprintln!("updated {}", path.display());
            } else {
                println!("updated {}", path.display());
            }
        }
        let save = m.value("save");
        if !save.is_empty() {
            std::fs::create_dir_all(save)?;
            let out = bench::baseline_path(std::path::Path::new(save), suite);
            std::fs::write(&out, bench::rows_to_json(&rows))?;
            if json_diff {
                eprintln!("saved {}", out.display());
            } else {
                println!("saved {}", out.display());
            }
        }
    }

    if json_diff {
        if diff_rows.is_empty() {
            println!("[]");
        } else {
            println!("[\n{}\n]", diff_rows.join(",\n"));
        }
    }
    for r in &regressions {
        eprintln!("regression: {r}");
    }
    if m.flag("compare") && !regressions.is_empty() {
        return Err(anyhow!(
            "{} bench(es) regressed past the {:.0}% threshold",
            regressions.len(),
            threshold * 100.0
        ));
    }
    Ok(())
}

/// Resolve a comma-list name filter (`all` or explicit names) against a
/// registry, with did-you-mean on unknown names.
fn name_filter(
    raw: &str,
    what: &str,
    known: &[&'static str],
) -> crate::Result<Vec<&'static str>> {
    if raw.trim().eq_ignore_ascii_case("all") {
        return Ok(known.to_vec());
    }
    let mut out = Vec::new();
    for item in split_list(raw) {
        match known.iter().find(|k| **k == item) {
            Some(k) => out.push(*k),
            None => return Err(unknown_value(what, item, known).into()),
        }
    }
    if out.is_empty() {
        return Err(anyhow!("empty {what} list"));
    }
    Ok(out)
}

/// `hecaton lint` — Layer-1 static analysis: run the determinism lint
/// over a source tree and exit non-zero on findings.
fn cmd_lint(m: &Matches) -> crate::Result<()> {
    let root = match m.pos(0) {
        Some(p) => std::path::PathBuf::from(p),
        None => crate::lint::default_src_root(),
    };
    let rules = name_filter(m.value("rules"), "lint rule", &crate::lint::rule_names())?;
    let findings: Vec<crate::lint::Finding> = crate::lint::lint_root(&root)?
        .into_iter()
        .filter(|f| rules.contains(&f.rule))
        .collect();
    for f in &findings {
        println!("{f}");
    }
    if !findings.is_empty() {
        return Err(anyhow!("{} lint finding(s) under {}", findings.len(), root.display()));
    }
    println!("lint clean: {} rule(s) over {}", rules.len(), root.display());
    Ok(())
}

/// Grid scenario files are audited on a capped prefix of their points
/// (auditing re-plans every point; a full grid belongs to `run`, not
/// `audit`). The cap is reported so coverage is never silently partial.
const AUDIT_GRID_CAP: usize = 8;

/// `hecaton audit` — Layer-2 static analysis: verify the invariant
/// contracts over the loader schema plus the given scenario file(s),
/// exiting non-zero on findings.
fn cmd_audit(m: &Matches) -> crate::Result<()> {
    let checks = name_filter(m.value("checks"), "audit check", &crate::audit::check_names())?;
    let files: Vec<std::path::PathBuf> = if m.flag("all-examples") {
        example_scenarios(m.value("examples-dir"))?
    } else {
        match m.pos(0) {
            Some(p) => vec![std::path::PathBuf::from(p)],
            None => Vec::new(),
        }
    };
    let mut findings: Vec<(String, crate::audit::AuditFinding)> = crate::audit::audit_static()
        .into_iter()
        .map(|f| ("loader".to_string(), f))
        .collect();
    let mut audited = 0usize;
    for path in &files {
        audited += audit_file(path, &mut findings)?;
    }
    findings.retain(|(_, f)| checks.contains(&f.check));
    for (label, f) in &findings {
        println!("{label}: {f}");
    }
    if !findings.is_empty() {
        return Err(anyhow!("{} audit finding(s)", findings.len()));
    }
    println!(
        "audit clean: {} check(s), {} scenario(s) across {} file(s) plus the loader schema",
        checks.len(),
        audited,
        files.len()
    );
    Ok(())
}

/// Audit one scenario file; returns the number of scenarios audited.
fn audit_file(
    path: &std::path::Path,
    out: &mut Vec<(String, crate::audit::AuditFinding)>,
) -> crate::Result<usize> {
    let label = path.display().to_string();
    match crate::config::file::load_scenario(&path.to_string_lossy())? {
        LoadedScenario::One(s) => {
            for f in crate::audit::audit_scenario(&s)? {
                out.push((label.clone(), f));
            }
            Ok(1)
        }
        LoadedScenario::Grid { grid, .. } => {
            let (points, _) = grid.points()?;
            let take = points.len().min(AUDIT_GRID_CAP);
            if take < points.len() {
                println!("{label}: audited {take} of {} grid points", points.len());
            }
            for s in points.iter().take(take) {
                for f in crate::audit::audit_scenario(s)? {
                    out.push((label.clone(), f));
                }
            }
            Ok(take)
        }
    }
}

/// The checked-in example scenarios: a non-recursive `*.toml` listing of
/// `dir` (default `examples/scenarios/` at the repo root), matching the
/// CI scenarios job's glob — fixture files in subdirectories
/// (intentionally invalid) are not picked up.
fn example_scenarios(dir: &str) -> crate::Result<Vec<std::path::PathBuf>> {
    let root = if dir.is_empty() {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
    } else {
        std::path::PathBuf::from(dir)
    };
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&root)
        .map_err(|e| anyhow!("cannot read {}: {e}", root.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "toml"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(anyhow!("no *.toml scenarios under {}", root.display()));
    }
    Ok(files)
}

fn print_info_table() -> crate::Result<()> {
    let mut t = Table::new(&["model", "hidden", "layers", "heads", "seq", "params"])
        .with_title("Model presets")
        .label_first();
    for name in eval_models() {
        let m = model_preset(name).unwrap();
        t.row(crate::table_row![
            m.name,
            m.hidden,
            m.layers,
            m.heads,
            m.seq_len,
            crate::util::fmt::count(m.total_params())
        ]);
    }
    println!("{}", t.render());
    let die = HardwareConfig::paper_die();
    println!(
        "Die: {} MACs/cycle @ {:.0} MHz = {} peak; {} + {} buffers; {} mm2",
        die.macs_per_cycle(),
        die.freq_hz / 1e6,
        crate::util::fmt::flops(die.peak_flops()),
        die.weight_buf,
        die.act_buf,
        die.area_mm2
    );
    let methods: Vec<&str> = Method::all().iter().map(|m| m.name()).collect();
    println!("TP methods: {}", methods.join(" | "));
    let engines: Vec<&str> = EngineKind::all().iter().map(|e| e.name()).collect();
    println!("Engine backends: {}", engines.join(" | "));
    let topos: Vec<&str> = TopologyKind::all().iter().map(|t| t.name()).collect();
    println!("NoP topologies (--topo / --topos): {}", topos.join(" | "));
    println!("Search objectives (hecaton search --objective, typo-suggesting):");
    for name in crate::search::OBJECTIVE_NAMES {
        println!("  {name}: {}", crate::search::Objective::describe(name));
    }
    println!(
        "Sweep axes: --models --meshes --packages --drams --topos --methods --engines \
         (comma lists; most accept 'all'), --threads, --format table|csv|json \
         (`hecaton search` takes the same axes plus --objective/--budget-sram-mib)"
    );
    println!(
        "Cluster knobs (simulate + sweep): --n-packages/--dp/--pp \
         (dp x pp must equal the package count; TP stays in-package), \
         --inter-bw substrate|optical|fat-tree|<GB/s>"
    );
    println!(
        "Memory knobs (simulate + sweep): --checkpoint none|auto|every-<k> \
         (activation recomputation at fusion-group boundaries), \
         --sram-mib <MiB>|none (enforced per-die SRAM capacity; infeasible \
         schedules error instead of being priced) — see `hecaton reproduce sram`"
    );
    println!("Cluster presets (see `hecaton reproduce cluster`):");
    for name in cluster_presets() {
        let (m, c) = cluster_preset(name).expect("preset resolves");
        println!(
            "  {name}: {} on {} x {}x{}-die packages, dp={} x pp={}, {:.0} GB/s fabric",
            m.name,
            c.packages,
            c.package_hw.mesh_rows,
            c.package_hw.mesh_cols,
            c.dp,
            c.pp,
            c.inter.gbs()
        );
    }
    println!(
        "Scenario files: `hecaton run <file.toml>` executes a single scenario \
         ([model]/[hardware]/[cluster]/[options]) or a [sweep] grid — checked-in \
         examples live in examples/scenarios/; `hecaton info --format json` emits \
         these presets machine-readably"
    );
    println!("Functional (train) presets: tiny, e2e-100m — see aot.py DEPLOYMENTS");
    println!("Static analysis (`hecaton lint` / `hecaton audit`, typo-suggesting):");
    for r in crate::lint::RULES {
        println!("  lint  {}: {}", r.name, r.summary);
    }
    for c in crate::audit::CHECKS {
        println!("  audit {}: {}", c.name, c.summary);
    }
    Ok(())
}

/// Machine-readable presets (`info --format json`): models, methods,
/// engines, packages, DRAM kinds and cluster presets.
fn info_json() -> String {
    let mut out = String::from("{\n  \"models\": [\n");
    for (i, name) in all_model_presets().iter().enumerate() {
        let m = model_preset(name).expect("preset resolves");
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"hidden\": {}, \"intermediate\": {}, \"layers\": {}, \
             \"heads\": {}, \"kv_heads\": {}, \"seq_len\": {}, \"batch\": {}, \
             \"vocab\": {}, \"params\": {}}}",
            m.name,
            m.hidden,
            m.intermediate,
            m.layers,
            m.heads,
            m.kv_heads,
            m.seq_len,
            m.batch,
            m.vocab,
            m.total_params()
        ));
    }
    out.push_str("\n  ],\n");
    let quoted = |names: &[&str]| {
        names
            .iter()
            .map(|n| format!("\"{n}\""))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let methods: Vec<&str> = Method::all().iter().map(|m| m.name()).collect();
    let engines: Vec<&str> = EngineKind::all().iter().map(|e| e.name()).collect();
    let topos: Vec<&str> = TopologyKind::all().iter().map(|t| t.name()).collect();
    out.push_str(&format!("  \"methods\": [{}],\n", quoted(&methods)));
    out.push_str(&format!("  \"engines\": [{}],\n", quoted(&engines)));
    out.push_str(&format!("  \"topologies\": [{}],\n", quoted(&topos)));
    out.push_str(&format!(
        "  \"objectives\": [{}],\n",
        quoted(&crate::search::OBJECTIVE_NAMES)
    ));
    out.push_str(&format!(
        "  \"fabrics\": [{}],\n",
        quoted(&["substrate", "optical", "fat-tree"])
    ));
    out.push_str(&format!("  \"lint_rules\": [{}],\n", quoted(&crate::lint::rule_names())));
    out.push_str(&format!(
        "  \"audit_checks\": [{}],\n",
        quoted(&crate::audit::check_names())
    ));
    out.push_str(&format!("  \"packages\": [{}],\n", quoted(&["standard", "advanced"])));
    out.push_str(&format!(
        "  \"drams\": [{}],\n",
        quoted(&["ddr4-3200", "ddr5-6400", "hbm2"])
    ));
    out.push_str("  \"cluster_presets\": [\n");
    for (i, name) in cluster_presets().iter().enumerate() {
        let (m, c) = cluster_preset(name).expect("preset resolves");
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"model\": \"{}\", \"packages\": {}, \"dp\": {}, \
             \"pp\": {}, \"mesh\": \"{}x{}\", \"inter_gbs\": {}}}",
            m.name,
            c.packages,
            c.dp,
            c.pp,
            c.package_hw.mesh_rows,
            c.package_hw.mesh_cols,
            c.inter.gbs()
        ));
    }
    out.push_str("\n  ]\n}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn app_parses_all_subcommands() {
        let a = app();
        assert!(a.parse(&argv(&["simulate", "--model", "tiny"])).unwrap().is_some());
        assert!(a.parse(&argv(&["sweep", "--models", "tiny"])).unwrap().is_some());
        assert!(a.parse(&argv(&["run", "scenario.toml"])).unwrap().is_some());
        assert!(a.parse(&argv(&["reproduce", "fig8"])).unwrap().is_some());
        assert!(a.parse(&argv(&["train", "--steps", "3"])).unwrap().is_some());
        assert!(a.parse(&argv(&["info"])).unwrap().is_some());
        assert!(a
            .parse(&argv(&["bench", "--suite", "hotpath", "--quick", "--compare"]))
            .unwrap()
            .is_some());
        assert!(a
            .parse(&argv(&["search", "--objective", "pareto", "--models", "tiny"]))
            .unwrap()
            .is_some());
        assert!(a.parse(&argv(&["bogus"])).is_err());
    }

    #[test]
    fn app_parses_lint_and_audit() {
        let a = app();
        assert!(a.parse(&argv(&["lint"])).unwrap().is_some());
        assert!(a.parse(&argv(&["lint", "--rules", "hash-order"])).unwrap().is_some());
        assert!(a.parse(&argv(&["audit", "--all-examples"])).unwrap().is_some());
        assert!(a
            .parse(&argv(&["audit", "scenario.toml", "--checks", "schema"]))
            .unwrap()
            .is_some());
    }

    #[test]
    fn misspelled_command_suggests_audit() {
        let e = app().parse(&argv(&["adit"])).unwrap_err();
        assert!(format!("{e}").contains("did you mean 'audit'?"), "{e}");
    }

    #[test]
    fn unknown_rule_and_check_names_get_suggestions() {
        let e = name_filter("hash-ordr", "lint rule", &crate::lint::rule_names()).unwrap_err();
        assert!(format!("{e:#}").contains("did you mean 'hash-order'?"), "{e}");
        let e =
            name_filter("bound-sandwch", "audit check", &crate::audit::check_names()).unwrap_err();
        assert!(format!("{e:#}").contains("did you mean 'bound-sandwich'?"), "{e}");
    }

    #[test]
    fn name_filter_resolves_all_and_explicit_lists() {
        let all = name_filter("all", "audit check", &crate::audit::check_names()).unwrap();
        assert_eq!(all, crate::audit::check_names());
        let two = name_filter("schema,task-graph", "audit check", &all).unwrap();
        assert_eq!(two, vec!["schema", "task-graph"]);
    }

    #[test]
    fn info_json_lists_analysis_registries() {
        let j = info_json();
        assert!(j.contains("\"lint_rules\""));
        assert!(j.contains("\"hash-order\""));
        assert!(j.contains("\"audit_checks\""));
        assert!(j.contains("\"bound-sandwich\""));
    }

    /// `search` runs end to end through the real CLI in every format, and
    /// objective typos / bad pairings error with suggestions.
    #[test]
    fn search_command_runs_and_validates() {
        let a = app();
        for (objective, format) in
            [("latency", "table"), ("energy", "csv"), ("pareto", "json")]
        {
            let m = a
                .parse(&argv(&[
                    "search",
                    "--objective",
                    objective,
                    "--models",
                    "tinyllama-1.1b",
                    "--meshes",
                    "2x2,4x4",
                    "--methods",
                    "hecaton,flat-ring",
                    "--threads",
                    "2",
                    "--format",
                    format,
                ]))
                .unwrap()
                .unwrap();
            cmd_search(&m).unwrap();
        }
        // Budget objective through the flag pair.
        let m = a
            .parse(&argv(&[
                "search", "--objective", "latency-under-sram", "--budget-sram-mib", "256",
                "--models", "tinyllama-1.1b", "--meshes", "4x4", "--methods", "hecaton",
            ]))
            .unwrap()
            .unwrap();
        cmd_search(&m).unwrap();
        // Typos and bad pairings are clean errors.
        let m = a
            .parse(&argv(&["search", "--objective", "latancy"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_search(&m).unwrap_err());
        assert!(e.contains("did you mean 'latency'"), "{e}");
        for args in [
            vec!["search", "--objective", "latency-under-sram"], // missing budget
            vec!["search", "--objective", "latency", "--budget-sram-mib", "64"],
            vec!["search", "--batch", "0"],
            vec!["search", "--format", "yaml"],
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            assert!(cmd_search(&m).is_err(), "{args:?} should error cleanly");
        }
    }

    /// `bench --json` demands --compare (it *is* the compare diff).
    #[test]
    fn bench_json_requires_compare() {
        let a = app();
        let m = a
            .parse(&argv(&["bench", "--suite", "hotpath", "--quick", "--json"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_bench(&m).unwrap_err());
        assert!(e.contains("--compare"), "{e}");
    }

    /// Regression: `simulate` rejects degenerate hardware with a clean
    /// error (no panic), for both --mesh and --dies forms.
    #[test]
    fn simulate_rejects_degenerate_hardware() {
        let a = app();
        for args in [
            vec!["simulate", "--mesh", "0x4"],
            vec!["simulate", "--mesh", "4x0"],
            vec!["simulate", "--dies", "0"],
            vec!["simulate", "--dies", "12"], // not a perfect square
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            let r = cmd_simulate(&m);
            assert!(r.is_err(), "{args:?} should error cleanly");
        }
    }

    /// Regression: breakdown percentages guard against a zero/non-finite
    /// denominator instead of printing NaN%.
    #[test]
    fn pct_guards_zero_total() {
        assert_eq!(pct(0.5, 0.0, 1), "—");
        assert_eq!(pct(0.5, f64::NAN, 1), "—");
        assert_eq!(pct(f64::NAN, 1.0, 1), "—");
        assert_eq!(pct(0.5, 2.0, 1), "25.0%");
        assert_eq!(pct(0.25, 1.0, 2), "25.00%");
    }

    /// Typos on name-valued flags come back with a suggestion — the
    /// shared `scenario::axis`/`util::cli` path serves every subcommand.
    #[test]
    fn flag_typos_get_suggestions() {
        let a = app();
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--method", "hecatn"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("did you mean 'hecaton'"), "{e}");
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--engine", "evnt"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("did you mean 'event'"), "{e}");
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--engine", "pakcet"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("did you mean 'packet'"), "{e}");
        // The topology axis speaks the same suggestion protocol.
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--topo", "tours"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("did you mean 'torus'"), "{e}");
        // Case-insensitive values keep working.
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "TinyLlama-1.1B", "--dies", "16", "--method", "HECATON",
                "--engine", "Analytic",
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
    }

    #[test]
    fn sweep_command_runs_all_formats() {
        let a = app();
        for format in ["table", "csv", "json"] {
            let m = a
                .parse(&argv(&[
                    "sweep",
                    "--models",
                    "tinyllama-1.1b",
                    "--meshes",
                    "4x4",
                    "--methods",
                    "hecaton,flat-ring",
                    "--threads",
                    "2",
                    "--format",
                    format,
                ]))
                .unwrap()
                .unwrap();
            cmd_sweep(&m).unwrap();
        }
        let bad = a
            .parse(&argv(&["sweep", "--format", "yaml"]))
            .unwrap()
            .unwrap();
        assert!(cmd_sweep(&bad).is_err());
    }

    #[test]
    fn simulate_command_runs() {
        let a = app();
        let m = a
            .parse(&argv(&["simulate", "--model", "tinyllama-1.1b", "--dies", "16"]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
    }

    /// The topology axis works end-to-end through the real CLI: a torus
    /// simulate runs, and a mesh+torus sweep expands the grid.
    #[test]
    fn simulate_and_sweep_accept_topology_axis() {
        let a = app();
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--topo", "torus",
                "--method", "torus-ring",
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
        let m = a
            .parse(&argv(&[
                "sweep", "--models", "tinyllama-1.1b", "--meshes", "4x4", "--topos", "all",
                "--methods", "hecaton", "--threads", "1",
            ]))
            .unwrap()
            .unwrap();
        cmd_sweep(&m).unwrap();
    }

    #[test]
    fn simulate_command_runs_event_engine() {
        let a = app();
        for engine in ["event", "event-prefetch", "packet"] {
            let m = a
                .parse(&argv(&[
                    "simulate",
                    "--model",
                    "tinyllama-1.1b",
                    "--dies",
                    "16",
                    "--engine",
                    engine,
                ]))
                .unwrap()
                .unwrap();
            cmd_simulate(&m).unwrap();
        }
        let bad = a
            .parse(&argv(&["simulate", "--engine", "bogus"]))
            .unwrap()
            .unwrap();
        assert!(cmd_simulate(&bad).is_err());
    }

    /// `--trace` exports per-queue occupancy JSONL on a packet-engine
    /// cluster run, and errors cleanly on the shapes it cannot trace.
    #[test]
    fn simulate_trace_exports_packet_queue_occupancy() {
        let a = app();
        let path = std::env::temp_dir().join("hecaton_cli_trace_test.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16",
                "--n-packages", "4", "--dp", "2", "--pp", "2",
                "--engine", "packet", "--trace", &path_s,
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let first = body.lines().next().expect("trace has samples");
        assert!(first.starts_with('{') && first.ends_with('}'), "{first}");
        for key in ["\"t\"", "\"queue\"", "\"pkts\"", "\"dropped\""] {
            assert!(first.contains(key), "{first} missing {key}");
        }
        // Wrong engine: clean error pointing at --engine packet.
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16",
                "--n-packages", "4", "--dp", "2", "--pp", "2",
                "--engine", "event", "--trace", &path_s,
            ]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("--engine packet"), "{e}");
        // Single-package target: nothing crosses the fabric to trace.
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16",
                "--engine", "packet", "--trace", &path_s,
            ]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("cluster"), "{e}");
    }

    #[test]
    fn info_runs_table_and_json() {
        let a = app();
        let m = a.parse(&argv(&["info"])).unwrap().unwrap();
        cmd_info(&m).unwrap();
        let m = a.parse(&argv(&["info", "--format", "json"])).unwrap().unwrap();
        cmd_info(&m).unwrap();
        let json = info_json();
        assert!(json.contains("\"models\""));
        assert!(json.contains("\"tinyllama-1.1b\""));
        assert!(json.contains("\"cluster_presets\""));
        assert!(json.contains("\"405b-cluster\""));
        assert!(json.contains("\"topologies\": [\"mesh\", \"torus\"]"));
        assert!(json.contains(
            "\"engines\": [\"analytic\", \"event\", \"event-prefetch\", \"packet\"]"
        ));
        assert!(json.contains("\"fat-tree\""));
        let bad = a.parse(&argv(&["info", "--format", "yaml"])).unwrap().unwrap();
        assert!(cmd_info(&bad).is_err());
    }

    /// `simulate` with cluster knobs routes through the cluster simulator;
    /// malformed shapes error cleanly.
    #[test]
    fn simulate_cluster_flags() {
        let a = app();
        let m = a
            .parse(&argv(&[
                "simulate",
                "--model",
                "tinyllama-1.1b",
                "--dies",
                "16",
                "--n-packages",
                "4",
                "--dp",
                "2",
                "--pp",
                "2",
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();
        for args in [
            // dp x pp != packages
            vec!["simulate", "--dies", "16", "--n-packages", "4", "--dp", "2", "--pp", "1"],
            // unknown fabric
            vec!["simulate", "--dies", "16", "--dp", "2", "--n-packages", "2", "--inter-bw", "x"],
            // unknown fabric is rejected even on the degenerate 1x1x1 shape
            vec!["simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--inter-bw", "warp"],
            // pp deeper than the layer stack
            vec![
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--n-packages", "23",
                "--dp", "1", "--pp", "23",
            ],
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            assert!(cmd_simulate(&m).is_err(), "{args:?} should error cleanly");
        }
    }

    /// The acceptance flow through the real CLI: an enforced SRAM limit
    /// with checkpointing off errors cleanly (pointing at the fix), the
    /// same scenario with `--checkpoint auto` runs, and bad values on the
    /// new flags are rejected.
    #[test]
    fn simulate_sram_and_checkpoint_flags() {
        let a = app();
        let base = [
            "simulate", "--model", "tinyllama-1.1b", "--dies", "64", "--sram-mib", "12",
        ];
        let m = a.parse(&argv(&base)).unwrap().unwrap();
        let e = format!("{:#}", cmd_simulate(&m).unwrap_err());
        assert!(e.contains("SRAM-infeasible"), "{e}");
        assert!(e.contains("--checkpoint auto"), "{e}");

        let mut ok_args = base.to_vec();
        ok_args.extend(["--checkpoint", "auto"]);
        let m = a.parse(&argv(&ok_args)).unwrap().unwrap();
        cmd_simulate(&m).unwrap();

        // Explicit every-k also runs (no enforcement without --sram-mib).
        let m = a
            .parse(&argv(&[
                "simulate", "--model", "tinyllama-1.1b", "--dies", "16", "--checkpoint",
                "every-2",
            ]))
            .unwrap()
            .unwrap();
        cmd_simulate(&m).unwrap();

        for args in [
            vec!["simulate", "--dies", "16", "--checkpoint", "sometimes"],
            vec!["simulate", "--dies", "16", "--sram-mib", "-3"],
            vec!["simulate", "--dies", "16", "--sram-mib", "lots"],
        ] {
            let m = a.parse(&argv(&args)).unwrap().unwrap();
            assert!(cmd_simulate(&m).is_err(), "{args:?} should error cleanly");
        }
    }

    #[test]
    fn sweep_checkpoint_and_sram_axes_run() {
        let a = app();
        let m = a
            .parse(&argv(&[
                "sweep",
                "--models",
                "tinyllama-1.1b",
                "--meshes",
                "4x4",
                "--methods",
                "hecaton",
                "--checkpoint",
                "none,every-2",
                "--sram-mib",
                "none,64",
                "--threads",
                "2",
                "--format",
                "csv",
            ]))
            .unwrap()
            .unwrap();
        cmd_sweep(&m).unwrap();
    }

    #[test]
    fn sweep_cluster_axes_run_all_formats() {
        let a = app();
        for format in ["table", "csv", "json"] {
            let m = a
                .parse(&argv(&[
                    "sweep",
                    "--models",
                    "tinyllama-1.1b",
                    "--meshes",
                    "4x4",
                    "--methods",
                    "hecaton",
                    "--n-packages",
                    "4",
                    "--dp",
                    "1,2,4",
                    "--pp",
                    "1,2,4",
                    "--threads",
                    "2",
                    "--format",
                    format,
                ]))
                .unwrap()
                .unwrap();
            cmd_sweep(&m).unwrap();
        }
        // A grid whose every combination is inconsistent errors out.
        let bad = a
            .parse(&argv(&[
                "sweep",
                "--models",
                "tinyllama-1.1b",
                "--meshes",
                "4x4",
                "--n-packages",
                "4",
                "--dp",
                "3",
                "--pp",
                "3",
            ]))
            .unwrap()
            .unwrap();
        assert!(cmd_sweep(&bad).is_err());
    }

    /// `run` executes both single-scenario and grid files, with CLI
    /// overrides for threads/format.
    #[test]
    fn run_command_executes_scenario_files() {
        let dir = std::env::temp_dir();
        let single = dir.join("hecaton_cli_run_single.toml");
        std::fs::write(
            &single,
            "[model]\npreset = \"tinyllama-1.1b\"\n[hardware]\ndies = 16\n\
             [cluster]\npackages = 2\ndp = 2\npp = 1\n",
        )
        .unwrap();
        let a = app();
        let m = a
            .parse(&argv(&["run", single.to_str().unwrap()]))
            .unwrap()
            .unwrap();
        cmd_run(&m).unwrap();

        let grid = dir.join("hecaton_cli_run_grid.toml");
        std::fs::write(
            &grid,
            "[sweep]\nmodels = [\"tinyllama-1.1b\"]\nmeshes = [\"4x4\"]\n\
             methods = [\"hecaton\", \"flat-ring\"]\n\n[options]\nthreads = 2\nformat = \"csv\"\n",
        )
        .unwrap();
        let m = a.parse(&argv(&["run", grid.to_str().unwrap()])).unwrap().unwrap();
        cmd_run(&m).unwrap();
        // CLI override of the file's format.
        let m = a
            .parse(&argv(&["run", grid.to_str().unwrap(), "--format", "json"]))
            .unwrap()
            .unwrap();
        cmd_run(&m).unwrap();
        let m = a
            .parse(&argv(&["run", grid.to_str().unwrap(), "--format", "yaml"]))
            .unwrap()
            .unwrap();
        assert!(cmd_run(&m).is_err());

        // Grid-only overrides on a single-scenario file are rejected, not
        // silently ignored.
        let m = a
            .parse(&argv(&["run", single.to_str().unwrap(), "--format", "json"]))
            .unwrap()
            .unwrap();
        let e = format!("{:#}", cmd_run(&m).unwrap_err());
        assert!(e.contains("only applies to [sweep] grid files"), "{e}");

        // Missing files and missing positionals error cleanly.
        let m = a
            .parse(&argv(&["run", "/nonexistent/nope.toml"]))
            .unwrap()
            .unwrap();
        assert!(cmd_run(&m).is_err());
    }
}
