//! `hecaton bench` — the in-tree perf harness with a *committed* baseline.
//!
//! Three suites guard the evaluate() hot path and the search layer (see
//! ARCHITECTURE.md §Performance and §Search):
//!
//! * `hotpath` — repeated single-scenario evaluation: the cold path
//!   (fresh plan cache + fresh engine buffers every call) against the
//!   service path ([`crate::scenario::EvalScratch`]: reused plan + arena),
//!   plus the overlap-chain and raw-task-graph kernels fresh vs arena.
//! * `sweep` — the Fig. 8 grid (2 packagings × 4 paper pairings × 4
//!   methods) serial vs parallel vs warm-cache through
//!   [`crate::scenario::run_on`].
//! * `search` — branch-and-bound co-exploration ([`crate::search`])
//!   against the exhaustive sweep on the `reproduce search` grid, plus
//!   *recorded* evaluated-point fractions so the same `--compare`
//!   threshold that catches slowdowns also catches pruning-effectiveness
//!   regressions.
//!
//! Results are compared against `BENCH_hotpath.json` / `BENCH_sweep.json`
//! / `BENCH_search.json` at the repo root; `--compare` fails the run when a bench's median
//! regresses past the threshold, and `--update` rewrites the baselines in
//! place. The JSON row shape is byte-compatible with the `harness = false`
//! bench binaries in `benches/` (`finish_with_json`), so either producer
//! can refresh a baseline.
//!
//! Baselines are *machine-local*: numbers measured on one machine are not
//! comparable to another's, which is why CI runs with a generous
//! warn-level threshold and uploads its own refreshed JSON as an artifact
//! instead of trusting absolute numbers.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::anyhow;

use crate::config::presets::{model_preset, paper_pairings};
use crate::config::{DramKind, HardwareConfig, PackageKind};
use crate::memory::dram::DramModel;
use crate::nop::analytic::Method;
use crate::scenario::{run_all, run_on, EvalScratch, Scenario};
use crate::search::{Objective, SearchConfig};
use crate::sched::pipeline::{
    overlap_chain_event, overlap_chain_event_in, GroupStage, EVENT_ITEM_CAP,
};
use crate::sim::engine::{EngineArena, EventEngine, Service};
use crate::sim::sweep::PlanCache;
use crate::sim::system::EngineKind;
use crate::util::json::{self, Json};
use crate::util::stats::Summary;
use crate::util::{Bytes, Seconds};

/// The suite names `--suite all` expands to, in run order.
pub const SUITES: [&str; 3] = ["hotpath", "sweep", "search"];

/// Harness knobs. `quick` shrinks the per-bench measurement window (CI
/// and smoke runs); the *workload* under each bench name never changes,
/// so rows from quick and standard runs stay comparable in shape (though
/// quick medians are noisier).
#[derive(Debug, Clone, Copy, Default)]
pub struct BenchOpts {
    pub quick: bool,
}

impl BenchOpts {
    fn target_secs(&self) -> f64 {
        if self.quick {
            0.25
        } else {
            2.0
        }
    }
    fn max_iters(&self) -> usize {
        if self.quick {
            25
        } else {
            200
        }
    }
}

/// One measured bench: the unit of the committed baseline files.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    pub suite: String,
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Adaptive timer: warm up once, then iterate until the target time or
/// the iteration cap — the same policy as `benches/common`.
struct Runner {
    suite: &'static str,
    opts: BenchOpts,
    rows: Vec<BenchRow>,
}

impl Runner {
    fn new(suite: &'static str, opts: BenchOpts) -> Runner {
        eprintln!("== bench suite: {suite} ==");
        Runner {
            suite,
            opts,
            rows: Vec::new(),
        }
    }

    fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        f(); // warmup
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.opts.target_secs()
            && samples.len() < self.opts.max_iters()
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::from(&samples).expect("at least one sample");
        println!(
            "bench {:40} {:>6} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            name,
            s.n,
            crate::util::fmt::seconds(s.mean),
            crate::util::fmt::seconds(s.median),
            crate::util::fmt::seconds(s.p95),
        );
        self.rows.push(BenchRow {
            suite: self.suite.to_string(),
            name: name.to_string(),
            iters: s.n,
            mean_s: s.mean,
            median_s: s.median,
            p95_s: s.p95,
            min_s: s.min,
            max_s: s.max,
        });
    }

    /// Record a derived *metric* (not a timing) as a row: every stat field
    /// carries the value, so `compare()` ratios it like any median and the
    /// `--threshold` gate guards it. Used for deterministic quantities
    /// (e.g. the search's evaluated-point fraction) where any drift is a
    /// real change, not measurement noise.
    fn record(&mut self, name: &str, value: f64) {
        println!("bench {:40} {:>6} iters  value {:>12.6}", name, 1, value);
        self.rows.push(BenchRow {
            suite: self.suite.to_string(),
            name: name.to_string(),
            iters: 1,
            mean_s: value,
            median_s: value,
            p95_s: value,
            min_s: value,
            max_s: value,
        });
    }
}

/// Run one named suite. Unknown names error with the valid set.
pub fn run_suite(suite: &str, opts: BenchOpts) -> crate::Result<Vec<BenchRow>> {
    match suite {
        "hotpath" => Ok(hotpath_suite(opts)),
        "sweep" => Ok(sweep_suite(opts)),
        "search" => Ok(search_suite(opts)),
        other => Err(anyhow!(
            "unknown bench suite '{other}' (expected hotpath | sweep | search | all)"
        )),
    }
}

fn hotpath_suite(opts: BenchOpts) -> Vec<BenchRow> {
    let mut r = Runner::new("hotpath", opts);

    // Repeated single-scenario evaluation: the service-path acceptance
    // pair. Event engine on a paper pairing, so both planning and the
    // event kernel are on the measured path.
    let scen = Scenario::builder(model_preset("llama2-7b").expect("preset exists"))
        .dies(64)
        .method(Method::Hecaton)
        .engine(EngineKind::Event)
        .build()
        .expect("paper pairing scenario is valid");
    r.bench("hotpath/evaluate_cold", || {
        std::hint::black_box(scen.evaluate_on(&PlanCache::new()).expect("evaluates"));
    });
    let cache = PlanCache::new();
    let mut scratch = EvalScratch::new();
    r.bench("hotpath/evaluate_service", || {
        std::hint::black_box(scen.evaluate_with(&cache, &mut scratch).expect("evaluates"));
    });

    // Overlap-chain kernel: fresh engine per call vs reused arena.
    let hw = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let dram = DramModel::new(&hw);
    let chain: Vec<GroupStage> = (0..8)
        .map(|_| GroupStage {
            on_package: Seconds::ms(20.0),
            dram_bytes: Bytes::gib(4.0),
            n_minibatches: 256,
        })
        .collect();
    r.bench("hotpath/overlap_chain_fresh", || {
        std::hint::black_box(overlap_chain_event(&chain, &dram, true));
    });
    let mut arena = EngineArena::new();
    r.bench("hotpath/overlap_chain_arena", || {
        std::hint::black_box(overlap_chain_event_in(
            &mut arena,
            &chain,
            &dram,
            true,
            EVENT_ITEM_CAP,
        ));
    });

    // Raw task graph: allocation cost isolated from any model content.
    fn build_graph(eng: &mut EventEngine) {
        let pkg = eng.fifo("pkg");
        let fabric = eng.fair("fabric", 1e11);
        let mut prev = None;
        for i in 0..2_000u64 {
            let deps: Vec<_> = prev.into_iter().collect();
            let d = eng.task(fabric, Service::Transfer(Bytes(1e6 + i as f64)), &deps);
            let p = eng.task(pkg, Service::Busy(Seconds(1e-5)), &[d]);
            prev = Some(p);
        }
    }
    r.bench("hotpath/task_graph_4k_fresh", || {
        let mut eng = EventEngine::new();
        build_graph(&mut eng);
        std::hint::black_box(eng.run().makespan);
    });
    let mut arena = EngineArena::new();
    r.bench("hotpath/task_graph_4k_arena", || {
        arena.engine.reset();
        build_graph(&mut arena.engine);
        arena.kernel.execute(&arena.engine);
        std::hint::black_box(arena.kernel.makespan());
    });

    // Packet engine on a cluster shape: the per-flow queue/transport
    // simulation is the measured path (the on-package chain itself rides
    // the event arena). Gated by the same `--threshold` as every row.
    let pkt = Scenario::builder(model_preset("tinyllama-1.1b").expect("preset exists"))
        .dies(16)
        .cluster(4, 2, 2)
        .engine(EngineKind::Packet)
        .build()
        .expect("valid cluster scenario");
    let cache = PlanCache::new();
    let mut scratch = EvalScratch::new();
    r.bench("hotpath/evaluate_packet", || {
        std::hint::black_box(pkt.evaluate_with(&cache, &mut scratch).expect("evaluates"));
    });

    r.rows
}

/// The Fig. 8 grid as scenarios: 2 packagings × 4 pairings × 4 methods.
fn fig8_scenarios() -> Vec<Scenario> {
    let mut out = Vec::new();
    for package in [PackageKind::Standard, PackageKind::Advanced] {
        for w in paper_pairings() {
            for method in Method::all() {
                out.push(
                    Scenario::builder(w.model.clone())
                        .dies(w.dies)
                        .package(package)
                        .method(method)
                        .build()
                        .expect("paper pairing scenarios are valid"),
                );
            }
        }
    }
    out
}

fn sweep_suite(opts: BenchOpts) -> Vec<BenchRow> {
    let mut r = Runner::new("sweep", opts);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    eprintln!("(running on {cores} cores)");

    let scenarios = fig8_scenarios();
    r.bench("sweep/fig8_grid_serial", || {
        std::hint::black_box(run_on(&PlanCache::new(), &scenarios, 1).expect("grid evaluates"));
    });
    r.bench("sweep/fig8_grid_parallel", || {
        std::hint::black_box(run_on(&PlanCache::new(), &scenarios, 0).expect("grid evaluates"));
    });
    let warm = PlanCache::new();
    let _ = run_on(&warm, &scenarios, 0).expect("grid evaluates");
    r.bench("sweep/fig8_grid_warm_cache", || {
        std::hint::black_box(run_on(&warm, &scenarios, 0).expect("grid evaluates"));
    });

    r.rows
}

fn search_suite(opts: BenchOpts) -> Vec<BenchRow> {
    let mut r = Runner::new("search", opts);

    // The `reproduce search` co-exploration grid: the exhaustive sweep is
    // the baseline the pruned searches must beat.
    let grid = crate::report::search::grid();
    let (points, _) = grid.points().expect("search grid expands");
    r.bench("search/exhaustive_grid", || {
        std::hint::black_box(run_all(&points).expect("grid evaluates"));
    });
    for (name, objective) in [
        ("search/pruned_latency", Objective::Latency),
        ("search/pruned_pareto", Objective::Pareto),
    ] {
        r.bench(name, || {
            std::hint::black_box(
                crate::search::run(&grid, &SearchConfig::new(objective), &PlanCache::new())
                    .expect("search grid is valid"),
            );
        });
    }

    // Pruning effectiveness as guarded rows. The fraction is deterministic
    // for a fixed grid, so a ratio past the threshold means the bounds got
    // looser (or grouping broke) — a perf regression the timing rows alone
    // could hide on a faster machine.
    for (name, objective) in [
        ("search/evaluated_fraction_latency", Objective::Latency),
        ("search/evaluated_fraction_pareto", Objective::Pareto),
    ] {
        let out = crate::search::run(&grid, &SearchConfig::new(objective), &PlanCache::new())
            .expect("search grid is valid");
        r.record(name, out.evaluated_fraction());
    }

    r.rows
}

// ───────────────────────── baseline files ─────────────────────────

/// `BENCH_<suite>.json` under `dir`.
pub fn baseline_path(dir: &Path, suite: &str) -> PathBuf {
    dir.join(format!("BENCH_{suite}.json"))
}

/// Where the committed baselines live: the repo root. The binary may run
/// from the root or from `rust/`, so probe both for a repo marker.
pub fn default_baseline_dir() -> PathBuf {
    for dir in [".", ".."] {
        let d = Path::new(dir);
        if d.join("PAPER.md").exists() || d.join("BENCH_hotpath.json").exists() {
            return d.to_path_buf();
        }
    }
    PathBuf::from(".")
}

/// Serialize rows in the exact layout of `benches/common`
/// `finish_with_json`: a pretty array of one-line objects, `{:e}` floats,
/// trailing newline. An empty slice serializes as the bootstrap form
/// `[]` — the committed placeholder before the first `--update`.
pub fn rows_to_json(rows: &[BenchRow]) -> String {
    if rows.is_empty() {
        return "[]\n".to_string();
    }
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        s.push_str(&format!(
            "  {{\"suite\": \"{}\", \"name\": \"{}\", \"iters\": {}, \
             \"mean_s\": {:e}, \"median_s\": {:e}, \"p95_s\": {:e}, \
             \"min_s\": {:e}, \"max_s\": {:e}}}",
            json_escape(&r.suite),
            json_escape(&r.name),
            r.iters,
            r.mean_s,
            r.median_s,
            r.p95_s,
            r.min_s,
            r.max_s,
        ));
    }
    s.push_str("\n]\n");
    s
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Parse a baseline file's rows (the inverse of [`rows_to_json`], and of
/// the `benches/` binaries' output).
pub fn parse_rows(text: &str) -> crate::Result<Vec<BenchRow>> {
    let doc = json::parse(text).map_err(|e| anyhow!("{e}"))?;
    let arr = doc
        .as_array()
        .ok_or_else(|| anyhow!("bench baseline must be a JSON array"))?;
    arr.iter()
        .map(|row| {
            let num = |k: &str| {
                row.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("bench row missing numeric field '{k}'"))
            };
            let text = |k: &str| {
                row.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("bench row missing string field '{k}'"))
            };
            Ok(BenchRow {
                suite: text("suite")?,
                name: text("name")?,
                iters: num("iters")? as usize,
                mean_s: num("mean_s")?,
                median_s: num("median_s")?,
                p95_s: num("p95_s")?,
                min_s: num("min_s")?,
                max_s: num("max_s")?,
            })
        })
        .collect()
}

// ───────────────────────── comparison ─────────────────────────

/// One baseline-vs-current pairing, matched by bench name.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    pub name: String,
    pub base_median: f64,
    pub new_median: f64,
}

impl Delta {
    /// `new / base` — above 1.0 is a slowdown.
    pub fn ratio(&self) -> f64 {
        self.new_median / self.base_median
    }
    /// Whether this pairing regressed past `threshold` (e.g. `0.2` fails
    /// anything more than 20% slower than its baseline median).
    pub fn regressed(&self, threshold: f64) -> bool {
        self.ratio() > 1.0 + threshold
    }
}

/// Pair current rows with baseline rows by name, in current-row order.
/// Benches absent from the baseline (new benches) produce no delta —
/// they start guarding on the next `--update`.
pub fn compare(baseline: &[BenchRow], current: &[BenchRow]) -> Vec<Delta> {
    current
        .iter()
        .filter_map(|c| {
            baseline
                .iter()
                .find(|b| b.name == c.name)
                .map(|b| Delta {
                    name: c.name.clone(),
                    base_median: b.median_s,
                    new_median: c.median_s,
                })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str, median: f64) -> BenchRow {
        BenchRow {
            suite: "hotpath".to_string(),
            name: name.to_string(),
            iters: 10,
            mean_s: median,
            median_s: median,
            p95_s: median * 1.2,
            min_s: median * 0.8,
            max_s: median * 1.5,
        }
    }

    #[test]
    fn json_roundtrip_is_lossless_enough() {
        let rows = vec![row("a/b", 1.25e-3), row("c \"quoted\"", 2.0)];
        let text = rows_to_json(&rows);
        let back = parse_rows(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "a/b");
        assert_eq!(back[0].median_s, 1.25e-3);
        assert_eq!(back[1].name, "c \"quoted\"");
        assert_eq!(back[1].iters, 10);
    }

    #[test]
    fn empty_baseline_is_the_bootstrap_form() {
        assert_eq!(rows_to_json(&[]), "[]\n");
        assert!(parse_rows("[]\n").unwrap().is_empty());
        assert!(parse_rows("[]").unwrap().is_empty());
    }

    #[test]
    fn malformed_baseline_errors() {
        assert!(parse_rows("{\"not\": \"an array\"}").is_err());
        assert!(parse_rows("[{\"name\": \"x\"}]").is_err());
        assert!(parse_rows("nonsense").is_err());
    }

    #[test]
    fn compare_matches_by_name_and_flags_regressions() {
        let base = vec![row("a", 1.0), row("b", 1.0)];
        let cur = vec![row("a", 1.1), row("b", 1.5), row("new", 9.0)];
        let deltas = compare(&base, &cur);
        assert_eq!(deltas.len(), 2); // "new" has no baseline yet
        assert!(!deltas[0].regressed(0.2)); // 1.1x is inside 20%
        assert!(deltas[1].regressed(0.2)); // 1.5x is not
        assert!((deltas[1].ratio() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn suite_names_resolve() {
        for s in SUITES {
            // Only validate dispatch; running the suites is the CLI's job.
            assert!(["hotpath", "sweep", "search"].contains(&s));
        }
        assert!(run_suite("bogus", BenchOpts::default()).is_err());
    }

    #[test]
    fn baseline_paths() {
        assert_eq!(
            baseline_path(Path::new(".."), "sweep"),
            PathBuf::from("../BENCH_sweep.json")
        );
    }
}
