//! End-to-end training driver over the functional coordinator.
//!
//! Drives [`crate::coordinator::Coordinator`] through full batches of a
//! synthetic corpus, accumulating gradients across mini-batches (the
//! paper's Fig. 6 inner loop: `for n = 0 → N−1 … dW +=`) and applying one
//! SGD step per batch. Logs the loss curve — the artifact
//! `examples/train_e2e.rs` records into EXPERIMENTS.md.

pub mod data;

use crate::coordinator::{Coordinator, MeshCfg};
use crate::train::data::Corpus;

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    /// Wall-clock of the whole batch (fwd+bwd+update).
    pub wall: std::time::Duration,
}

/// Training-loop configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> TrainCfg {
        TrainCfg {
            steps: 20,
            lr: 0.5,
            seed: 1234,
        }
    }
}

/// Run the training loop; returns the per-step logs.
///
/// Each step draws `batch_tokens / minibatch_tokens` mini-batches from the
/// corpus, accumulates gradients on the dies, then applies SGD.
pub fn train(
    coord: &mut Coordinator,
    corpus: &mut Corpus,
    cfg: TrainCfg,
) -> crate::Result<Vec<StepLog>> {
    let mesh: MeshCfg = coord.cfg.clone();
    let w = mesh.tokens;
    let n_mb = (mesh.model.batch_tokens() / w).max(1);
    let mut logs = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let t0 = std::time::Instant::now();
        let mut loss_sum = 0.0f32;
        for _ in 0..n_mb {
            let (tokens, targets) = corpus.minibatch(w);
            loss_sum += coord.grad_step(&tokens, &targets)?;
        }
        // Scale the step to the mean gradient over mini-batches.
        coord.sgd_step(cfg.lr / n_mb as f32)?;
        let log = StepLog {
            step,
            loss: loss_sum / n_mb as f32,
            wall: t0.elapsed(),
        };
        crate::log_info!(
            "step {:>3}  loss {:.4}  ({} mini-batches, {:?})",
            log.step,
            log.loss,
            n_mb,
            log.wall
        );
        logs.push(log);
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{coord_model, MeshCfg};

    #[test]
    fn e2e_training_loss_decreases_on_mesh() {
        if !crate::runtime::artifact_dir().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let model = coord_model("tiny").unwrap();
        let mut corpus = Corpus::next_token(model.vocab, model.seq_len, 99);
        let cfg = MeshCfg::new(model, 2, 2, 64);
        let mut coord = Coordinator::new(cfg, 7).unwrap();
        let logs = train(
            &mut coord,
            &mut corpus,
            TrainCfg {
                steps: 16,
                lr: 1.0,
                seed: 0,
            },
        )
        .unwrap();
        assert_eq!(logs.len(), 16);
        let first = logs.first().unwrap().loss;
        let last = logs.last().unwrap().loss;
        assert!(last < first - 0.25, "loss {first} -> {last}");
        coord.shutdown().unwrap();
    }
}
