//! Synthetic training corpora for the end-to-end examples.
//!
//! Two tasks with learnable structure:
//! * **next-token** — target is `(token + 1) mod V` on random tokens:
//!   learnable by any model with an attention-free path (tests the
//!   embedding→FFN→head pipeline).
//! * **induction** — sequences of repeated random bigram patterns, where
//!   predicting the next token requires attending to the previous
//!   occurrence — exercises the attention path specifically.

use crate::util::rng::Rng;

/// Corpus kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    NextToken,
    Induction,
}

/// A streaming synthetic corpus.
pub struct Corpus {
    rng: Rng,
    vocab: usize,
    seq_len: usize,
    task: Task,
}

impl Corpus {
    pub fn next_token(vocab: usize, seq_len: usize, seed: u64) -> Corpus {
        Corpus {
            rng: Rng::new(seed),
            vocab,
            seq_len,
            task: Task::NextToken,
        }
    }

    pub fn induction(vocab: usize, seq_len: usize, seed: u64) -> Corpus {
        Corpus {
            rng: Rng::new(seed),
            vocab,
            seq_len,
            task: Task::Induction,
        }
    }

    /// Draw a mini-batch of `tokens` tokens (whole sequences) and its
    /// next-token targets.
    pub fn minibatch(&mut self, tokens: usize) -> (Vec<u32>, Vec<i32>) {
        assert!(tokens % self.seq_len == 0, "whole sequences only");
        let seqs = tokens / self.seq_len;
        let mut toks = Vec::with_capacity(tokens);
        for _ in 0..seqs {
            toks.extend(self.sequence());
        }
        let targets = Self::targets_for(&toks, self.seq_len, self.vocab);
        (toks, targets)
    }

    fn sequence(&mut self) -> Vec<u32> {
        match self.task {
            Task::NextToken => (0..self.seq_len)
                .map(|_| self.rng.below(self.vocab as u64) as u32)
                .collect(),
            Task::Induction => {
                // A short random motif repeated to fill the sequence.
                let motif_len = 4.max(self.seq_len / 8);
                let motif: Vec<u32> = (0..motif_len)
                    .map(|_| self.rng.below(self.vocab as u64) as u32)
                    .collect();
                (0..self.seq_len).map(|i| motif[i % motif_len]).collect()
            }
        }
    }

    /// Next-token targets within each sequence (the last position wraps to
    /// the sequence's own first token — every position keeps a defined,
    /// learnable target).
    fn targets_for(tokens: &[u32], seq_len: usize, vocab: usize) -> Vec<i32> {
        tokens
            .chunks(seq_len)
            .flat_map(|seq| {
                (0..seq.len()).map(move |i| {
                    let next = seq[(i + 1) % seq.len()];
                    (next % vocab as u32) as i32
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minibatch_shapes_and_ranges() {
        let mut c = Corpus::next_token(64, 32, 1);
        let (t, y) = c.minibatch(96);
        assert_eq!(t.len(), 96);
        assert_eq!(y.len(), 96);
        assert!(t.iter().all(|&x| x < 64));
        assert!(y.iter().all(|&x| (0..64).contains(&x)));
    }

    #[test]
    fn targets_are_next_tokens() {
        let toks = vec![5u32, 6, 7, 8];
        let y = Corpus::targets_for(&toks, 4, 64);
        assert_eq!(y, vec![6, 7, 8, 5]); // wraps within the sequence
    }

    #[test]
    fn induction_sequences_repeat() {
        let mut c = Corpus::induction(64, 32, 2);
        let (t, _) = c.minibatch(32);
        let motif_len = 4.max(32 / 8);
        for i in motif_len..32 {
            assert_eq!(t[i], t[i - motif_len]);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::next_token(64, 32, 3);
        let mut b = Corpus::next_token(64, 32, 3);
        assert_eq!(a.minibatch(64), b.minibatch(64));
    }

    #[test]
    #[should_panic(expected = "whole sequences")]
    fn partial_sequences_rejected() {
        Corpus::next_token(64, 32, 1).minibatch(40);
    }
}
