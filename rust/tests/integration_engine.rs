//! Integration tests for the discrete-event engine refactor: event-backend
//! vs analytic parity across every training method, per-pass parity of the
//! step schedules against the Table III closed forms, and the congestion
//! scenarios only the event engine can express.

use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, LinkConfig, PackageKind};
use hecaton::nop::analytic::{table3, Block, Method, NopParams, Pass};
use hecaton::nop::collective::{
    event_time_concurrent, flat_ring_all_reduce_schedule, flat_ring_phase_schedule,
    ring_step_schedule, torus_all_reduce_schedule, CollectiveKind, CollectiveSchedule,
};
use hecaton::config::cluster::{ClusterConfig, InterKind, InterPkgLink};
use hecaton::sim::cluster::ClusterPlan;
use hecaton::sim::engine::EngineArena;
use hecaton::sim::sweep::PlanCache;
use hecaton::sim::system::{simulate_engine, EngineKind, PlanOptions, SimPlan};
use hecaton::util::prop;
use hecaton::util::{Bytes, Seconds};

fn link() -> LinkConfig {
    LinkConfig::for_package(PackageKind::Standard)
}

/// `--engine event` end-to-end: on uncongested square meshes the event
/// backend reproduces the analytic closed forms within 1% for **all four
/// methods** (each simulated batch exercises both the forward and backward
/// pass stages), and the latency breakdown stays self-consistent.
#[test]
fn event_vs_analytic_parity_property() {
    prop::check("simulate event == analytic (<=1%)", 24, |g| {
        let model = *g.pick(&["tinyllama-1.1b", "gpt3-6.7b"]);
        let dies = *g.pick(&[4usize, 16, 64]);
        let dram = *g.pick(&[DramKind::Ddr4_3200, DramKind::Ddr5_6400]);
        let package = *g.pick(&[PackageKind::Standard, PackageKind::Advanced]);
        let m = model_preset(model).unwrap();
        let hw = HardwareConfig::square(dies, package, dram);
        for method in Method::all() {
            let an = simulate_engine(&m, &hw, method, EngineKind::Analytic);
            let ev = simulate_engine(&m, &hw, method, EngineKind::Event);
            prop::assert_close(
                ev.latency.raw(),
                an.latency.raw(),
                1e-2,
                format!("{model}/{dies}/{method:?} latency"),
            )?;
            prop::assert_close(
                ev.breakdown.total().raw(),
                ev.latency.raw(),
                2e-2,
                format!("{model}/{dies}/{method:?} breakdown sum"),
            )?;
            // Energy only depends on timing through the static term.
            prop::assert_close(
                ev.energy_total.raw(),
                an.energy_total.raw(),
                1e-2,
                format!("{model}/{dies}/{method:?} energy"),
            )?;
        }
        Ok(())
    });
}

/// Per-pass parity at the NoP level: the composed step schedules of the
/// ring-based methods, replayed on the event engine, land exactly on the
/// Table III closed forms for both passes.
#[test]
fn schedules_match_table3_both_passes() {
    let l = link();
    for n in [16usize, 64, 256] {
        let rn = (n as f64).sqrt() as usize;
        let act = Bytes(1.0e8);
        let p = NopParams {
            n,
            alpha: l.latency,
            gamma: act.over_bandwidth(l.bandwidth),
            xi: Seconds::ZERO,
        };
        let per_ring = act / rn as f64;
        let ag = |v: Bytes| ring_step_schedule(CollectiveKind::AllGather, rn, v);
        let rs = |v: Bytes| ring_step_schedule(CollectiveKind::ReduceScatter, rn, v);

        // Hecaton fwd Attention: AG(X) → RS(QKV) → AG(A) → RS(O).
        let fwd = ag(per_ring)
            .then(rs(per_ring * 3.0))
            .then(ag(per_ring))
            .then(rs(per_ring));
        // Hecaton bwd Attention: per linear AG(dOut) → RS(dIn) → AG(in).
        let bwd = ag(per_ring * 3.0)
            .then(rs(per_ring))
            .then(ag(per_ring))
            .then(ag(per_ring))
            .then(rs(per_ring))
            .then(ag(per_ring));
        for (sched, pass) in [(fwd, Pass::Fwd), (bwd, Pass::Bwd)] {
            let (l_cf, t_cf) = table3(Method::Hecaton, Block::Attention, pass, &p);
            let want = (l_cf + t_cf).raw();
            let got = sched.event_time(&l).raw();
            assert!(
                (got - want).abs() / want < 1e-9,
                "hecaton {pass:?} n={n}: {got} vs {want}"
            );
        }

        // Flat ring: AR fwd; AR + AG bwd.
        let fwd = flat_ring_all_reduce_schedule(n, act);
        let bwd = flat_ring_all_reduce_schedule(n, act).then(flat_ring_phase_schedule(n, act));
        for (sched, pass) in [(fwd, Pass::Fwd), (bwd, Pass::Bwd)] {
            let (l_cf, t_cf) = table3(Method::FlatRing, Block::Ffn, pass, &p);
            let want = (l_cf + t_cf).raw();
            let got = sched.event_time(&l).raw();
            assert!(
                (got - want).abs() / want < 1e-9,
                "flat-ring {pass:?} n={n}: {got} vs {want}"
            );
        }

        // Torus fwd (bwd is covered end-to-end by the simulate-level
        // parity test; its Table III row is 1.5× this schedule).
        let torus = torus_all_reduce_schedule(rn, act);
        let (l_cf, t_cf) = table3(Method::TorusRing, Block::Attention, Pass::Fwd, &p);
        let want = (l_cf + t_cf).raw();
        let got = torus.event_time(&l).raw();
        assert!(
            (got - want).abs() / want < 1e-9,
            "torus fwd n={n}: {got} vs {want}"
        );
    }
}

/// Scenarios the closed forms cannot express, end-to-end.
#[test]
fn congestion_scenarios_are_expressible() {
    let l = link();

    // (a) Link contention: two collectives on a shared fabric serialize;
    // the analytic `alongside` (disjoint links) is a strict lower bound.
    let a = ring_step_schedule(CollectiveKind::AllGather, 8, Bytes::mib(32.0));
    let b = ring_step_schedule(CollectiveKind::ReduceScatter, 8, Bytes::mib(32.0));
    let ideal = a.cost(&l).alongside(b.cost(&l)).total().raw();
    let contended = event_time_concurrent(&[&a, &b], &l).raw();
    assert!(contended > ideal * 1.5, "{contended} vs {ideal}");

    // (b) Skewed meshes run end-to-end under the event engine.
    let m = model_preset("tinyllama-1.1b").unwrap();
    for (rows, cols) in [(2usize, 8usize), (1, 16), (4, 4)] {
        let hw = HardwareConfig::mesh(rows, cols, PackageKind::Standard, DramKind::Ddr5_6400);
        let r = simulate_engine(&m, &hw, Method::Hecaton, EngineKind::Event);
        assert!(r.latency.raw() > 0.0, "{rows}x{cols}");
        assert!(
            (r.breakdown.total().raw() - r.latency.raw()).abs() / r.latency.raw() < 0.02,
            "{rows}x{cols} breakdown"
        );
    }

    // (c) Overlap slack: prefetch never loses to the serialized event
    // schedule, which never loses to... itself; analytic stays the
    // reference within 1%.
    let m = model_preset("llama2-70b").unwrap();
    let hw = HardwareConfig::square(256, PackageKind::Standard, DramKind::Ddr4_3200);
    let an = simulate_engine(&m, &hw, Method::Hecaton, EngineKind::Analytic);
    let ev = simulate_engine(&m, &hw, Method::Hecaton, EngineKind::Event);
    let pre = simulate_engine(&m, &hw, Method::Hecaton, EngineKind::EventPrefetch);
    assert!((ev.latency.raw() - an.latency.raw()).abs() / an.latency.raw() < 1e-2);
    assert!(pre.latency <= ev.latency);
}

/// An empty schedule is free and a composed schedule's event time is the
/// sum of its parts (barrier semantics).
#[test]
fn schedule_composition_event_times_add() {
    let l = link();
    assert_eq!(CollectiveSchedule::default().event_time(&l), Seconds::ZERO);
    prop::check("then() adds event times", 32, |g| {
        let n = g.usize_range(2, 10);
        let s1 = ring_step_schedule(CollectiveKind::AllGather, n, Bytes(g.f64_range(1e4, 1e8)));
        let s2 = flat_ring_phase_schedule(n, Bytes(g.f64_range(1e4, 1e8)));
        let sum = s1.event_time(&l) + s2.event_time(&l);
        let composed = s1.then(s2).event_time(&l);
        prop::assert_close(composed.raw(), sum.raw(), 1e-9, "composition")
    });
}

/// Tentpole invariant, package side: the calendar time-wheel pops events
/// in exactly the legacy single-heap (time, seq) order, so every method ×
/// engine × mesh produces **bitwise-identical** results on a wheel arena,
/// a heap-only arena, and a fresh per-call engine — and reusing one arena
/// across all of these configs never leaks state between runs. (f64 Debug
/// formatting is shortest-roundtrip, so equal Debug strings ⇔ equal bits.)
#[test]
fn time_wheel_matches_heap_order_bitwise_on_packages() {
    let mut wheel = EngineArena::new();
    let mut heap = EngineArena::heap_only();
    for model in ["tinyllama-1.1b", "gpt3-6.7b"] {
        let m = model_preset(model).unwrap();
        for (rows, cols) in [(4usize, 4usize), (2, 8)] {
            let hw = HardwareConfig::mesh(rows, cols, PackageKind::Standard, DramKind::Ddr5_6400);
            for method in Method::all() {
                let plan = SimPlan::build(&m, &hw, method, PlanOptions::default());
                for engine in EngineKind::all() {
                    let tag = format!("{model}/{rows}x{cols}/{method:?}/{engine:?}");
                    let w = plan.time_in(engine, &mut wheel);
                    let h = plan.time_in(engine, &mut heap);
                    let fresh = plan.time(engine);
                    assert_eq!(format!("{w:?}"), format!("{h:?}"), "wheel vs heap: {tag}");
                    assert_eq!(format!("{w:?}"), format!("{fresh:?}"), "arena vs fresh: {tag}");
                }
            }
        }
    }
}

/// Tentpole invariant, cluster side: wheel ≡ heap ≡ fresh bitwise through
/// the full hybrid path (per-stage package plans + the 1F1B event DAG),
/// including a congested fabric slow enough to reorder the DAG's event
/// population relative to the healthy presets.
#[test]
fn time_wheel_matches_heap_order_bitwise_on_clusters() {
    let cache = PlanCache::new();
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let mut congested = InterPkgLink::preset(InterKind::Substrate);
    congested.bandwidth = 2.0e9; // 32× slower than the substrate preset
    congested.latency = Seconds::us(5.0);
    let mut wheel = EngineArena::new();
    let mut heap = EngineArena::heap_only();
    for (dp, pp) in [(2usize, 2usize), (1, 4), (4, 1)] {
        for inter in [InterPkgLink::preset(InterKind::Substrate), congested.clone()] {
            let c = ClusterConfig {
                packages: 4,
                dp,
                pp,
                inter,
                package_hw: hw.clone(),
            };
            for method in Method::all() {
                let plan = ClusterPlan::build(&m, &c, method, PlanOptions::default(), &cache)
                    .expect("shape is valid");
                for engine in EngineKind::all() {
                    let tag = format!("dp{dp}xpp{pp}/{method:?}/{engine:?}");
                    let w = plan.time_in(engine, &mut wheel);
                    let h = plan.time_in(engine, &mut heap);
                    let fresh = plan.time(engine);
                    assert_eq!(format!("{w:?}"), format!("{h:?}"), "wheel vs heap: {tag}");
                    assert_eq!(format!("{w:?}"), format!("{fresh:?}"), "arena vs fresh: {tag}");
                }
            }
        }
    }
}

/// The engine column reaches the report layer: the Fig. 8 grid can be
/// produced entirely by the event backend.
#[test]
fn fig8_grid_runs_on_event_engine() {
    let cells = hecaton::report::fig8::run_with(EngineKind::Event);
    assert_eq!(cells.len(), 2 * 4 * 4);
    for c in &cells {
        assert_eq!(c.result.engine, EngineKind::Event);
    }
    // Hecaton rows still normalize to 1 under the event engine.
    for c in cells.iter().filter(|c| c.method == Method::Hecaton) {
        assert!((c.rel_latency - 1.0).abs() < 1e-9);
    }
}
