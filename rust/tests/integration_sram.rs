//! Time-resolved SRAM occupancy & activation checkpointing — integration
//! and property tests:
//!
//! * **replay ↔ closed form** — the event-ordered occupancy replay's peak
//!   equals the group-list closed form within 1%, for all four TP methods
//!   across checkpoint policies and die budgets (the satellite property).
//! * **engine independence** — the event backends' re-replayed peak bytes
//!   are bitwise equal to the analytic replay's; only the peak *time*
//!   shifts, and stays within the engines' parity envelope.
//! * **acceptance flow** — an enforced SRAM limit below the schedule's
//!   peak errors cleanly with checkpointing off, becomes feasible with
//!   `checkpoint = auto`, and the whole configuration round-trips through
//!   scenario TOML.
//! * **legacy invariance** — with checkpointing off, plans carry exactly
//!   the pre-checkpointing pricing (spot-checked against the documented
//!   traffic closed form).

use hecaton::memory::sram::{closed_form_peak, replay};
use hecaton::prelude::*;
use hecaton::sched::checkpoint::Checkpoint;
use hecaton::sched::pipeline::{overlap, StageTimes};
use hecaton::sim::system::SimPlan;

fn plan_for(model: &str, dies: usize, method: Method, ck: Checkpoint) -> SimPlan {
    let m = model_preset(model).unwrap();
    let hw = HardwareConfig::square(dies, PackageKind::Standard, DramKind::Ddr5_6400);
    SimPlan::build(
        &m,
        &hw,
        method,
        PlanOptions {
            checkpoint: ck,
            ..PlanOptions::default()
        },
    )
}

/// Satellite property: the replayed occupancy peak equals the analytic
/// closed form within 1% on uncongested shapes, for all four methods.
#[test]
fn replayed_peak_matches_closed_form_for_all_methods() {
    for method in Method::all() {
        let shapes = [("tinyllama-1.1b", 16usize), ("tinyllama-1.1b", 64), ("llama2-7b", 64)];
        for (model, dies) in shapes {
            for ck in [Checkpoint::None, Checkpoint::EveryK(1), Checkpoint::EveryK(3)] {
                let plan = plan_for(model, dies, method, ck);
                let closed = closed_form_peak(plan.occupancy_shape(), &plan.groups, &plan.stages);
                let replayed = plan.occupancy.peak;
                let rel = (replayed.raw() - closed.raw()).abs() / closed.raw();
                assert!(
                    rel < 0.01,
                    "{method:?}/{model}@{dies}/{ck}: replay {replayed} vs closed form {closed} \
                     ({rel:.4} relative)"
                );
            }
        }
    }
}

/// The replay is span-driven: feeding it the analytic per-stage overlap
/// spans reproduces the plan's own report exactly.
#[test]
fn replay_with_analytic_spans_reproduces_the_plan_report() {
    let plan = plan_for("tinyllama-1.1b", 64, Method::Hecaton, Checkpoint::None);
    // Analytic spans rebuilt from the priced stages (uncongested closed
    // form; DRAM stream times from effective bandwidth).
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let dram = hecaton::memory::DramModel::new(&hw);
    let spans: Vec<hecaton::util::Seconds> = plan
        .stages
        .iter()
        .map(|st| {
            overlap(StageTimes {
                on_package: st.on_package,
                dram: dram.stream_time(st.dram_bytes),
                n_minibatches: st.n_minibatches,
            })
            .latency
        })
        .collect();
    let timeline = replay(plan.occupancy_shape(), &plan.groups, &plan.stages, &spans);
    assert_eq!(
        timeline.peak_bytes().raw().to_bits(),
        plan.occupancy.peak.raw().to_bits(),
        "same spans → same replay"
    );
    assert_eq!(
        timeline.peak_time().raw().to_bits(),
        plan.occupancy.peak_time.raw().to_bits()
    );
    assert_eq!(timeline.samples.len(), 2 * plan.groups.len() * m.layers);
}

/// Event backends re-replay occupancy under their own spans: identical
/// peak bytes (occupancy is byte-determined), peak time within the
/// event/analytic parity envelope on uncongested meshes.
#[test]
fn event_replay_keeps_peak_bytes_and_time_envelope() {
    for method in Method::all() {
        let m = model_preset("tinyllama-1.1b").unwrap();
        let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
        let plan = SimPlan::build(&m, &hw, method, PlanOptions::default());
        let an = plan.time(EngineKind::Analytic);
        for engine in [EngineKind::Event, EngineKind::EventPrefetch] {
            let ev = plan.time(engine);
            assert_eq!(
                ev.occupancy.peak.raw().to_bits(),
                an.occupancy.peak.raw().to_bits(),
                "{method:?}/{engine:?}: peak bytes"
            );
            // Peak time shifts with the backend's spans but stays in the
            // same regime (prefetch compresses interior fills).
            let (ta, te) = (an.occupancy.peak_time.raw(), ev.occupancy.peak_time.raw());
            if ta > 0.0 {
                let rel = (te - ta).abs() / ta;
                assert!(rel < 0.05, "{method:?}/{engine:?}: peak time drift {rel:.4}");
            }
        }
    }
}

/// Acceptance: enforced-limit infeasibility errors cleanly, `auto`
/// recovers, and the configuration round-trips through scenario TOML.
#[test]
fn enforced_limit_flow_and_toml_round_trip() {
    let model = model_preset("tinyllama-1.1b").unwrap();
    let scenario = |ck: Checkpoint| {
        Scenario::builder(model.clone())
            .dies(64)
            .sram_limit(hecaton::util::Bytes::mib(12.0))
            .checkpoint(ck)
            .build()
            .unwrap()
    };

    // Checkpointing off: the retained interior activations exceed 12 MiB
    // by orders of magnitude — a clean, actionable error.
    let e = format!("{:#}", evaluate(&scenario(Checkpoint::None)).unwrap_err());
    assert!(e.contains("SRAM-infeasible"), "{e}");
    assert!(e.contains("--checkpoint auto"), "{e}");

    // Auto: feasible, recomputing, and strictly slower than the
    // unconstrained legacy schedule (recompute is priced, not free).
    let auto = scenario(Checkpoint::Auto);
    let ok = evaluate(&auto).unwrap();
    assert!(ok.sim().occupancy.fits());
    assert!(ok.sim().checkpoint.recomputes());
    let unconstrained = Scenario::builder(model.clone()).dies(64).build().unwrap();
    let legacy = evaluate(&unconstrained).unwrap();
    assert!(ok.latency() > legacy.latency());

    // TOML round-trip: sram_mib + checkpoint survive serialization.
    let toml = auto.to_toml();
    assert!(toml.contains("sram_mib = 12"), "{toml}");
    assert!(toml.contains("checkpoint = \"auto\""), "{toml}");
    let hecaton::config::file::LoadedScenario::One(back) =
        hecaton::config::file::scenario_from_str(&toml).unwrap()
    else {
        panic!("round-trip must yield a single scenario");
    };
    assert_eq!(auto, back);
    let again = evaluate(&back).unwrap();
    assert_eq!(
        ok.latency().raw().to_bits(),
        again.latency().raw().to_bits(),
        "round-tripped scenario evaluates bitwise-identically"
    );
}

/// Cluster path: enforcement covers the 1F1B in-flight boundary term,
/// `auto` re-resolves against the capacity minus that share, and a
/// non-recomputing over-peak cluster errors with the shared diagnostic.
#[test]
fn cluster_enforcement_accounts_for_inflight_boundaries() {
    let model = model_preset("tinyllama-1.1b").unwrap();
    let scenario = |ck: Checkpoint| {
        Scenario::builder(model.clone())
            .dies(64)
            .cluster(2, 1, 2)
            .sram_limit(hecaton::util::Bytes::mib(12.0))
            .checkpoint(ck)
            .build()
            .unwrap()
    };
    let e = format!("{:#}", evaluate(&scenario(Checkpoint::None)).unwrap_err());
    assert!(e.contains("SRAM-infeasible"), "{e}");
    assert!(e.contains("in-flight 1F1B"), "{e}");
    assert!(e.contains("--checkpoint auto"), "{e}");

    let ok = evaluate(&scenario(Checkpoint::Auto)).unwrap();
    let detail = ok.cluster().expect("cluster scenario");
    assert!(
        detail.occupancy.fits(),
        "auto must fit including the in-flight term: peak {} vs {}",
        detail.occupancy.peak,
        detail.occupancy.capacity
    );
    assert!(detail.occupancy.acts_at_peak.raw() > 0.0);
    assert!(detail.stage.checkpoint.recomputes());
}

/// Legacy invariance: with checkpointing off the DRAM traffic follows the
/// documented closed form (2×/3× boundary + 3× weights per batch) — the
/// checkpoint-aware pricing cannot perturb the default path.
#[test]
fn none_policy_keeps_legacy_traffic_closed_form() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let plan = SimPlan::build(&m, &hw, Method::Hecaton, PlanOptions::default());
    let boundary = m.act_bytes();
    let weights: f64 = plan
        .groups
        .iter()
        .map(|g| g.weight_per_die.raw() * hw.n_dies() as f64)
        .sum();
    let want = (plan.groups.len() as f64 * 5.0 * boundary.raw() + 3.0 * weights)
        * m.layers as f64;
    let rel = (plan.dram_bytes.raw() - want).abs() / want;
    assert!(rel < 1e-9, "dram bytes {} vs closed form {want}", plan.dram_bytes);
    // And every-1 keeps the same boundary counts while recomputing only
    // where interiors exist.
    let ck1 = SimPlan::build(
        &m,
        &hw,
        Method::Hecaton,
        PlanOptions {
            checkpoint: Checkpoint::EveryK(1),
            ..PlanOptions::default()
        },
    );
    let rel = (ck1.dram_bytes.raw() - plan.dram_bytes.raw()).abs() / plan.dram_bytes.raw();
    assert!(rel < 1e-9, "every-1 checkpoints every boundary: same DRAM traffic");
}
