//! Cluster-layer integration tests:
//!
//! * **Degenerate-cluster regression** — 1 package, dp = pp = 1 reproduces
//!   the single-package simulator bitwise for all four TP methods and all
//!   three engine backends (the refactor's core invariant).
//! * **Engine parity** — event vs analytic cluster timing agree ≤1% on
//!   uncongested inter-package fabrics (property-tested over dp/pp/method
//!   shapes).
//! * **Sweep determinism** — the cluster sweep returns bitwise-identical
//!   results regardless of worker-thread count.

use hecaton::config::cluster::{ClusterConfig, FabricTopo, InterKind, InterPkgLink};
use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::scenario::{self, ScenarioGrid};
use hecaton::sim::cluster::{simulate_cluster, ClusterPlan};
use hecaton::sim::sweep::PlanCache;
use hecaton::sim::system::{simulate_engine, EngineKind, PlanOptions};
use hecaton::util::{prop, Seconds};

fn parity_hw() -> HardwareConfig {
    HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400)
}

#[test]
fn degenerate_cluster_is_bitwise_identical() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = parity_hw();
    for method in Method::all() {
        for engine in EngineKind::all() {
            let direct = simulate_engine(&m, &hw, method, engine);
            let c = simulate_cluster(&m, &ClusterConfig::single(hw.clone()), method, engine)
                .unwrap();
            let tag = format!("{method:?}/{engine:?}");
            assert_eq!(
                c.latency.raw().to_bits(),
                direct.latency.raw().to_bits(),
                "{tag}: latency"
            );
            assert_eq!(
                c.energy_total.raw().to_bits(),
                direct.energy_total.raw().to_bits(),
                "{tag}: energy"
            );
            // The embedded stage result IS the single-package result.
            assert_eq!(c.stage.breakdown, direct.breakdown, "{tag}: breakdown");
            assert_eq!(c.stage.energy, direct.energy, "{tag}: energy breakdown");
            assert_eq!(
                c.stage.latency.raw().to_bits(),
                direct.latency.raw().to_bits(),
                "{tag}: stage latency"
            );
            assert_eq!(c.stage.min_utilization, direct.min_utilization, "{tag}");
            assert_eq!(c.stage.n_minibatches, direct.n_minibatches, "{tag}");
            assert_eq!(c.stage.model, direct.model, "{tag}: model name");
            assert_eq!(c.stage.sram.feasible(), direct.sram.feasible(), "{tag}");
            // No cluster terms appear on the degenerate shape.
            assert_eq!(c.bubble, Seconds::ZERO, "{tag}");
            assert_eq!(c.p2p, Seconds::ZERO, "{tag}");
            assert_eq!(c.grad_allreduce, Seconds::ZERO, "{tag}");
            assert_eq!((c.packages, c.dp, c.pp), (1, 1, 1));
        }
    }
}

/// Event vs analytic cluster timing on a fast (uncongested) fabric: the
/// ≤1% acceptance bar, across dp/pp shapes and all TP methods. Prefetch
/// never loses to the plain event backend.
#[test]
fn cluster_engines_agree_on_uncongested_fabric() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = parity_hw();
    let fast = InterPkgLink {
        bandwidth: 1.0e15,
        latency: Seconds::ns(1.0),
        pj_per_bit: 1.0,
        topo: FabricTopo::PointToPoint,
    };
    prop::check("cluster event == analytic <= 1% (uncongested)", 24, |g| {
        let dp = *g.pick(&[1usize, 2, 4]);
        let pp = *g.pick(&[1usize, 2, 11]);
        let method = *g.pick(&Method::all());
        let cluster =
            ClusterConfig::try_new(hw.clone(), dp * pp, dp, pp, fast.clone()).unwrap();
        // One plan priced once, timed under every backend (the intended
        // multi-engine usage of the cluster plan).
        let cache = PlanCache::new();
        let plan =
            ClusterPlan::build(&m, &cluster, method, PlanOptions::default(), &cache).unwrap();
        let a = plan.time(EngineKind::Analytic);
        let e = plan.time(EngineKind::Event);
        prop::assert_close(
            e.latency.raw(),
            a.latency.raw(),
            1e-2,
            format!("dp={dp} pp={pp} {method:?}"),
        )?;
        let pre = plan.time(EngineKind::EventPrefetch);
        prop::assert_prop(
            pre.latency.raw() <= e.latency.raw() * (1.0 + 1e-9),
            format!("prefetch no slower (dp={dp} pp={pp} {method:?})"),
        )?;
        // Both backends report the same schedule shape and sane energy.
        prop::assert_prop(e.microbatches == a.microbatches, "microbatch depth")?;
        prop::assert_prop(
            e.energy_total.raw().is_finite() && e.energy_total.raw() > 0.0,
            "energy finite",
        )
    });
}

#[test]
fn cluster_sweep_parallel_matches_serial_bitwise() {
    let grid = ScenarioGrid {
        models: vec![model_preset("tinyllama-1.1b").unwrap()],
        meshes: vec![(4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic, EngineKind::Event],
        n_packages: vec![4],
        dp: vec![1, 2, 4],
        pp: vec![1, 2, 4],
        inter: vec![InterPkgLink::preset(InterKind::Substrate)],
        ..Default::default()
    };
    let (pts, skipped) = grid.points().unwrap();
    assert_eq!(pts.len(), 3 * Method::all().len() * 2, "3 valid shapes");
    assert!(skipped > 0, "the cross product contains inconsistent shapes");
    let serial = scenario::run_on(&PlanCache::new(), &pts, 1).unwrap();
    for threads in [2usize, 8] {
        let par = scenario::run_on(&PlanCache::new(), &pts, threads).unwrap();
        assert_eq!(par.len(), serial.len());
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(
                s.latency().raw().to_bits(),
                p.latency().raw().to_bits(),
                "threads={threads}: latency order/bits"
            );
            assert_eq!(
                s.energy_total().raw().to_bits(),
                p.energy_total().raw().to_bits(),
                "threads={threads}: energy bits"
            );
            let (sc, pc) = (s.cluster().unwrap(), p.cluster().unwrap());
            assert_eq!((sc.dp, sc.pp, sc.engine), (pc.dp, pc.pp, pc.engine));
        }
    }
}

/// The plan cache is shared across cluster scenarios: identical stage
/// sub-models (same mesh, method, shape) are priced once.
#[test]
fn cluster_points_share_stage_plans_through_the_cache() {
    let grid = ScenarioGrid {
        models: vec![model_preset("tinyllama-1.1b").unwrap()],
        meshes: vec![(4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        methods: vec![Method::Hecaton],
        engines: EngineKind::all().to_vec(),
        n_packages: vec![2],
        dp: vec![1, 2],
        pp: vec![1, 2],
        inter: vec![InterPkgLink::preset(InterKind::Substrate)],
        ..Default::default()
    };
    let (pts, _) = grid.points().unwrap();
    // Valid shapes for 2 packages: (dp=1,pp=2) and (dp=2,pp=1), times
    // every engine backend.
    assert_eq!(pts.len(), 2 * EngineKind::all().len());
    let cache = PlanCache::new();
    scenario::run_on(&cache, &pts, 1).unwrap();
    // Distinct stage sub-models: 11-layer/b1024 (pp=2) + 22-layer/b512 (dp=2).
    assert_eq!(cache.len(), 2, "stage plans are shared across engines and points");
    // The service path builds each cluster plan once per shape (engine-only
    // neighbors reuse the worker's EvalScratch without touching the cache):
    // two builds — pp=2 pricing its twin stage twice (1 miss + 1 hit) and
    // dp=2 pricing its single stage (1 miss).
    assert_eq!((cache.misses(), cache.hits()), (2, 1), "one build per shape");
}

/// Fabric-blind planning, asserted end-to-end: retargeting a priced plan
/// onto a different inter-package fabric is bitwise identical to building
/// a fresh plan against that fabric — across shapes, engines, and both a
/// healthy and a congested fabric. This is the invariant that lets the
/// sweep's service path reuse one cluster plan across the whole
/// `--inter-bw` axis.
#[test]
fn retarget_inter_matches_fresh_build_bitwise() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = parity_hw();
    let cache = PlanCache::new();
    let mut congested = InterPkgLink::preset(InterKind::Substrate);
    congested.bandwidth = 2.0e9;
    congested.latency = Seconds::us(5.0);
    let fabrics = [
        InterPkgLink::preset(InterKind::Substrate),
        InterPkgLink::preset(InterKind::Optical),
        congested,
    ];
    for (dp, pp) in [(2usize, 2usize), (1, 4)] {
        let base = ClusterConfig::try_new(hw.clone(), dp * pp, dp, pp, fabrics[0].clone()).unwrap();
        let mut retargeted =
            ClusterPlan::build(&m, &base, Method::Hecaton, PlanOptions::default(), &cache).unwrap();
        for inter in &fabrics {
            retargeted.retarget_inter(inter.clone());
            let mut cfg = base.clone();
            cfg.inter = inter.clone();
            let fresh =
                ClusterPlan::build(&m, &cfg, Method::Hecaton, PlanOptions::default(), &cache)
                    .unwrap();
            for engine in EngineKind::all() {
                let r = retargeted.time(engine);
                let f = fresh.time(engine);
                assert_eq!(
                    format!("{r:?}"),
                    format!("{f:?}"),
                    "dp{dp}xpp{pp}/{engine:?} @ {:.0e} B/s",
                    inter.bandwidth
                );
            }
        }
    }
}
