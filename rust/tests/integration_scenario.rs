//! Scenario-API integration tests:
//!
//! * **TOML round-trip** — builder → `to_toml` → loader → `evaluate` is
//!   bitwise identical to the CLI-flag path for every method × engine,
//!   on degenerate and cluster shapes (the api_redesign acceptance bar).
//! * **File-vs-flags parity** — `hecaton run examples/scenarios/
//!   405b_cluster.toml` produces exactly the scenario the equivalent
//!   `simulate --mesh 16x16 --n-packages 16 --dp 8 --pp 2` flags build.
//! * **Golden summaries** — every checked-in scenario file runs through
//!   the real `hecaton run` binary and must match its stored golden
//!   output; a missing golden is bootstrapped on first run so drift is
//!   caught from then on (delete the golden to regenerate intentionally).

use std::path::{Path, PathBuf};

use hecaton::config::file::{load_scenario, scenario_from_str, LoadedScenario};
use hecaton::prelude::*;
use hecaton::sim::cluster::simulate_cluster;
use hecaton::sim::system::simulate_engine;

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// Builder → serialize → load → evaluate: bitwise-equal to the direct
/// (CLI-flag) evaluation path for every method × engine, degenerate and
/// cluster shapes.
#[test]
fn toml_round_trip_is_bitwise_identical() {
    let model = model_preset("tinyllama-1.1b").unwrap();
    for method in Method::all() {
        for engine in EngineKind::all() {
            for cluster_shape in [None, Some((4usize, 2usize, 2usize))] {
                let mut b = Scenario::builder(model.clone())
                    .dies(16)
                    .method(method)
                    .engine(engine);
                if let Some((packages, dp, pp)) = cluster_shape {
                    b = b.cluster(packages, dp, pp);
                }
                let built = b.build().unwrap();
                let tag = format!("{method:?}/{engine:?}/{cluster_shape:?}");

                let toml = built.to_toml();
                let LoadedScenario::One(loaded) = scenario_from_str(&toml).unwrap() else {
                    panic!("{tag}: round-trip must yield a single scenario");
                };
                assert_eq!(built, loaded, "{tag}: scenario round-trip");

                let a = evaluate(&built).unwrap();
                let b2 = evaluate(&loaded).unwrap();
                assert_eq!(
                    a.latency().raw().to_bits(),
                    b2.latency().raw().to_bits(),
                    "{tag}: latency"
                );
                assert_eq!(
                    a.energy_total().raw().to_bits(),
                    b2.energy_total().raw().to_bits(),
                    "{tag}: energy"
                );

                // The legacy direct paths see the same bits.
                match built.cluster_config() {
                    None => {
                        let direct = simulate_engine(&model, built.hw(), method, engine);
                        assert_eq!(
                            a.latency().raw().to_bits(),
                            direct.latency.raw().to_bits(),
                            "{tag}: vs simulate_engine"
                        );
                        assert_eq!(
                            a.energy_total().raw().to_bits(),
                            direct.energy_total.raw().to_bits(),
                            "{tag}: vs simulate_engine energy"
                        );
                    }
                    Some(c) => {
                        let direct = simulate_cluster(&model, c, method, engine).unwrap();
                        assert_eq!(
                            a.latency().raw().to_bits(),
                            direct.latency.raw().to_bits(),
                            "{tag}: vs simulate_cluster"
                        );
                        assert_eq!(
                            a.energy_total().raw().to_bits(),
                            direct.energy_total.raw().to_bits(),
                            "{tag}: vs simulate_cluster energy"
                        );
                    }
                }
            }
        }
    }
}

/// Acceptance: `hecaton run examples/scenarios/405b_cluster.toml` is the
/// same evaluation as the equivalent `simulate --n-packages/--dp/--pp`
/// invocation — asserted at the scenario level (equality) and at the
/// result level (bitwise).
#[test]
fn run_405b_file_matches_simulate_flags() {
    let path = scenarios_dir().join("405b_cluster.toml");
    let LoadedScenario::One(from_file) = load_scenario(path.to_str().unwrap()).unwrap() else {
        panic!("405b_cluster.toml must hold a single scenario");
    };
    // What `simulate --model llama3.1-405b --mesh 16x16 --n-packages 16
    // --dp 8 --pp 2 --inter-bw substrate` builds.
    let from_flags = Scenario::builder(model_preset("llama3.1-405b").unwrap())
        .mesh(16, 16)
        .cluster(16, 8, 2)
        .method(Method::Hecaton)
        .engine(EngineKind::Analytic)
        .build()
        .unwrap();
    assert_eq!(from_file, from_flags, "file and flag scenarios must be identical");

    let a = evaluate(&from_file).unwrap();
    let b = evaluate(&from_flags).unwrap();
    assert_eq!(a.latency().raw().to_bits(), b.latency().raw().to_bits());
    assert_eq!(
        a.energy_total().raw().to_bits(),
        b.energy_total().raw().to_bits()
    );

    // The file mirrors the `405b-cluster` preset exactly.
    let (preset_model, preset_cluster) = cluster_preset("405b-cluster").unwrap();
    assert_eq!(preset_model, from_file.model);
    assert_eq!(&preset_cluster, from_file.cluster_config().unwrap());
}

/// Every checked-in scenario file loads, and single files collapse
/// degenerate cluster shapes exactly like the CLI.
#[test]
fn all_example_scenarios_load() {
    let dir = scenarios_dir();
    let mut tomls: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("examples/scenarios exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    tomls.sort();
    assert!(tomls.len() >= 4, "ship at least four example scenarios, found {tomls:?}");
    let mut saw_grid = false;
    let mut saw_cluster = false;
    for path in &tomls {
        match load_scenario(path.to_str().unwrap()).unwrap_or_else(|e| panic!("{path:?}: {e:#}"))
        {
            LoadedScenario::One(s) => saw_cluster |= s.is_cluster(),
            LoadedScenario::Grid { grid, .. } => {
                saw_grid = true;
                let (points, _) = grid.points().unwrap();
                assert!(!points.is_empty(), "{path:?}: grid expands to nothing");
            }
        }
    }
    assert!(saw_grid, "the example set includes a sweep grid");
    assert!(saw_cluster, "the example set includes a cluster scenario");
}

/// Golden-summary drift check over `examples/scenarios/` through the real
/// binary — the CI `scenarios` job runs this. Missing goldens are
/// bootstrapped (and must then be committed); existing goldens fail on
/// any byte of drift.
#[test]
fn example_scenarios_match_golden_summaries() {
    let dir = scenarios_dir();
    let golden_dir = dir.join("golden");
    std::fs::create_dir_all(&golden_dir).unwrap();
    let mut tomls: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    tomls.sort();
    for path in &tomls {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_hecaton"))
            .args(["run", path.to_str().unwrap()])
            .output()
            .unwrap_or_else(|e| panic!("spawning hecaton run {path:?}: {e}"));
        assert!(
            out.status.success(),
            "hecaton run {path:?} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8(out.stdout).expect("utf-8 table output");
        assert!(!stdout.is_empty(), "{path:?}: empty output");
        let stem = path.file_stem().unwrap().to_str().unwrap();
        let golden = golden_dir.join(format!("{stem}.golden"));
        if golden.exists() {
            let want = std::fs::read_to_string(&golden).unwrap();
            assert_eq!(
                stdout, want,
                "{path:?} drifted from {golden:?} — if the change is intentional, \
                 delete the golden file and re-run the tests to regenerate it"
            );
        } else {
            std::fs::write(&golden, &stdout).unwrap();
            eprintln!("bootstrapped golden {golden:?} — commit it to lock the summary");
        }
    }
}

/// Sweep determinism with the service path on: `run_on` (plan-affine
/// execution order + per-worker `EvalScratch` buffer/plan reuse) returns
/// results bitwise identical to evaluating each scenario in isolation on
/// a cold cache, at every worker count — on a mixed package + cluster
/// grid whose cluster points differ only in the inter-package fabric
/// (the axis the scratch reuses plans across).
#[test]
fn run_on_with_scratch_reuse_is_bitwise_deterministic() {
    let model = model_preset("tinyllama-1.1b").unwrap();
    let mut congested = InterPkgLink::preset(InterKind::Substrate);
    congested.bandwidth = 2.0e9;
    let mut pts: Vec<Scenario> = Vec::new();
    for engine in EngineKind::all() {
        for method in [Method::Hecaton, Method::FlatRing] {
            pts.push(
                Scenario::builder(model.clone())
                    .dies(16)
                    .method(method)
                    .engine(engine)
                    .build()
                    .unwrap(),
            );
            for inter in [InterPkgLink::preset(InterKind::Substrate), congested.clone()] {
                pts.push(
                    Scenario::builder(model.clone())
                        .dies(16)
                        .method(method)
                        .engine(engine)
                        .cluster(4, 2, 2)
                        .inter(inter)
                        .build()
                        .unwrap(),
                );
            }
        }
    }
    // Reference: each point alone, fresh cache — no reuse of any kind.
    let isolated: Vec<String> = pts
        .iter()
        .map(|s| format!("{:?}", s.evaluate_on(&PlanCache::new()).unwrap()))
        .collect();
    for threads in [1usize, 2, 4, 8] {
        let evals = run_on(&PlanCache::new(), &pts, threads).unwrap();
        assert_eq!(evals.len(), isolated.len());
        for (i, (e, want)) in evals.iter().zip(&isolated).enumerate() {
            assert_eq!(&format!("{e:?}"), want, "threads={threads} point={i}");
        }
    }
}
