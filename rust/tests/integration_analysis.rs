//! Static-analysis integration tests: the shipped crate must be clean
//! under its own verifiers.
//!
//! * **Lint** — `lint_root` over this crate's `src/` reports zero
//!   findings: every intentional exception carries an inline
//!   `// lint: allow(<rule>, <reason>)` directive.
//! * **Audit** — the loader schema and every checked-in single-scenario
//!   example pass all audit checks; the deliberately broken fixture in
//!   `examples/scenarios/audit/` fails to load with a did-you-mean
//!   suggestion (the CI failure-path smoke relies on this).

use std::path::{Path, PathBuf};

use hecaton::audit::{audit_scenario, audit_static};
use hecaton::config::file::{load_scenario, LoadedScenario};
use hecaton::lint::{default_src_root, lint_root};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// The crate's own sources carry zero lint findings.
#[test]
fn shipped_sources_lint_clean() {
    let findings = lint_root(&default_src_root()).unwrap();
    assert!(
        findings.is_empty(),
        "crate sources must lint clean:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// The loader schema wiring audits clean.
#[test]
fn loader_schema_audits_clean() {
    let findings = audit_static();
    assert!(findings.is_empty(), "schema findings: {findings:?}");
}

/// Every checked-in single-scenario example passes every audit check.
/// Grid files are covered by the CLI's `audit --all-examples` path; here
/// we keep the runtime bounded by auditing the concrete scenarios.
#[test]
fn example_scenarios_audit_clean() {
    let mut audited = 0usize;
    let mut entries: Vec<_> = std::fs::read_dir(scenarios_dir())
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.is_file() && p.extension().is_some_and(|x| x == "toml"))
        .collect();
    entries.sort();
    for path in entries {
        let loaded = load_scenario(&path.to_string_lossy()).unwrap();
        if let LoadedScenario::One(scenario) = loaded {
            let findings = audit_scenario(&scenario).unwrap();
            assert!(findings.is_empty(), "{}: {findings:?}", path.display());
            audited += 1;
        }
    }
    assert!(audited >= 2, "expected several concrete example scenarios");
}

/// The broken fixture is rejected at load time with a suggestion; it must
/// never start looking like a valid scenario.
#[test]
fn audit_fixture_fails_to_load_with_suggestion() {
    let path = scenarios_dir().join("audit/audit_fixture.toml");
    let err = load_scenario(&path.to_string_lossy()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("methids"), "{msg}");
    assert!(msg.contains("did you mean 'methods'?"), "{msg}");
}
