//! Packet-engine integration tests — the acceptance surface of the
//! `src/net/` subsystem:
//!
//! * **Uncongested parity** — the packet backend reproduces the event
//!   engine within 2% on package-level lowered traffic phases (all four
//!   TP methods' shapes × mesh/torus NoP topologies) and on cluster
//!   shapes over every fabric preset (point-to-point and fat-tree).
//! * **Incast divergence** — a many-to-one gradient all-reduce on an
//!   oversubscribed fat-tree is *strictly* slower under the packet
//!   backend than the fair-share event price, and the divergence
//!   responds monotonically to the queue-depth and ECN knobs.
//! * **Trace export** — [`ClusterPlan::packet_trace`] produces JSONL the
//!   CLI `--trace` flag ships verbatim.

use hecaton::comm::{CommOp, Group, Topology};
use hecaton::config::cluster::{ClusterConfig, InterKind, InterPkgLink};
use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, LinkConfig, PackageKind, TopologyKind};
use hecaton::net::{allreduce_packet, phase_packet_time, NetParams};
use hecaton::nop::analytic::Method;
use hecaton::sim::cluster::ClusterPlan;
use hecaton::sim::sweep::PlanCache;
use hecaton::sim::system::{EngineKind, PlanOptions};
use hecaton::util::{prop, Bytes};

fn package_hw() -> HardwareConfig {
    HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400)
}

/// ≤2% packet-vs-event parity on uncongested package-level collectives:
/// one representative lowered shape per TP method, on both NoP
/// topologies, across group sizes and volumes.
#[test]
fn packet_matches_event_on_uncongested_phases() {
    let link = LinkConfig::for_package(PackageKind::Standard);
    let np = NetParams::default();
    prop::check("packet == event <= 2% on lowered phases", 48, |g| {
        let topo = *g.pick(&[TopologyKind::Mesh2d, TopologyKind::Torus2d]);
        let n = *g.pick(&[4usize, 8, 16]);
        let vol = Bytes::mib(*g.pick(&[1.0f64, 16.0, 64.0]));
        // One op per method's lowering shape: Hecaton's row/col ring,
        // the flat (Megatron) ring, the 2D halved all-reduce, Optimus'
        // recursive-doubling broadcast.
        let op = match *g.pick(&[0usize, 1, 2, 3]) {
            0 => CommOp::all_gather(Group::BypassRing { n }, vol),
            1 => CommOp::all_reduce(Group::FlatRing { n }, vol),
            2 => CommOp::all_reduce(Group::Grid { side: 4 }, vol),
            _ => CommOp::broadcast(Group::Line { n }, vol),
        };
        let phase = topo.lower(op);
        let ev = phase.event_time(&link);
        let pkt = phase_packet_time(&phase, &link, &np);
        prop::assert_close(
            pkt.raw(),
            ev.raw(),
            2e-2,
            format!("{:?} n={n} vol={vol} op={:?}", topo, op.kind),
        )
    });
}

/// ≤2% packet-vs-event parity on uncongested cluster shapes: dp/pp
/// grids under every TP method over both fabric topologies (the
/// point-to-point presets and the switched fat-tree).
#[test]
fn packet_matches_event_on_uncongested_clusters() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = package_hw();
    prop::check("cluster packet == event <= 2% (uncongested)", 24, |g| {
        let dp = *g.pick(&[1usize, 2, 4]);
        let pp = *g.pick(&[1usize, 2]);
        let method = *g.pick(&Method::all());
        let kind = *g.pick(&[InterKind::Substrate, InterKind::Optical, InterKind::FatTree]);
        let cluster = ClusterConfig::try_new(
            hw.clone(),
            dp * pp,
            dp,
            pp,
            InterPkgLink::preset(kind),
        )
        .unwrap();
        let cache = PlanCache::new();
        let plan =
            ClusterPlan::build(&m, &cluster, method, PlanOptions::default(), &cache).unwrap();
        let e = plan.time(EngineKind::Event);
        let p = plan.time(EngineKind::Packet);
        prop::assert_close(
            p.latency.raw(),
            e.latency.raw(),
            2e-2,
            format!("dp={dp} pp={pp} {method:?} {kind:?}"),
        )?;
        prop::assert_prop(p.microbatches == e.microbatches, "schedule shape")?;
        prop::assert_prop(
            p.energy_total.raw().is_finite() && p.energy_total.raw() > 0.0,
            "energy finite",
        )
    });
}

/// The degenerate cluster (and any pp=1/dp=1 package chain) is bitwise
/// event under the packet engine — the on-package NoP is folded at plan
/// time, so there is nothing for queues to price.
#[test]
fn packet_package_path_is_bitwise_event() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = package_hw();
    for method in Method::all() {
        let e = hecaton::sim::system::simulate_engine(&m, &hw, method, EngineKind::Event);
        let p = hecaton::sim::system::simulate_engine(&m, &hw, method, EngineKind::Packet);
        assert_eq!(
            e.latency.raw().to_bits(),
            p.latency.raw().to_bits(),
            "{method:?}: on-package packet == event"
        );
    }
}

/// Incast: 8 replicas firing their gradient all-reduce into an
/// oversubscribed fat-tree core. The fair-share event price cannot see
/// the core queue overflowing; the packet backend must be *strictly*
/// slower.
#[test]
fn fat_tree_incast_packet_strictly_exceeds_event() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = package_hw();
    let inter = InterPkgLink::parse("fat-tree:8").unwrap();
    let cluster = ClusterConfig::try_new(hw, 8, 8, 1, inter).unwrap();
    let cache = PlanCache::new();
    let plan =
        ClusterPlan::build(&m, &cluster, Method::Hecaton, PlanOptions::default(), &cache)
            .unwrap();
    let e = plan.time(EngineKind::Event);
    let p = plan.time(EngineKind::Packet);
    assert!(
        p.latency > e.latency,
        "incast must cost more under queues: packet {} vs event {}",
        p.latency,
        e.latency
    );
    // The divergence is the all-reduce term: stage compute is identical.
    assert_eq!(
        p.stage.latency.raw().to_bits(),
        e.stage.latency.raw().to_bits(),
        "stage timing is engine-shared"
    );
}

/// The congestion knobs act the right way at cluster volumes: deeper
/// queues absorb the incast burst (less retransmission), and a later ECN
/// threshold delays backoff — both can only speed up the transfer, and
/// the shallow/early baseline stays above the fluid fair share.
#[test]
fn incast_knobs_are_monotone_at_cluster_volumes() {
    let inter = InterPkgLink::parse("fat-tree:8").unwrap();
    let dp = 8usize;
    let vol = Bytes::mib(64.0);
    let hop_debt = inter.hop_latency() * 6.0; // 2·⌈log₂ 8⌉ switched hops
    let shallow = NetParams { queue_pkts: 32.0, ecn_pkts: 8.0, ..NetParams::default() };
    let deep = NetParams { queue_pkts: 4096.0, ecn_pkts: 8.0, ..NetParams::default() };
    let late_ecn = NetParams { queue_pkts: 32.0, ecn_pkts: 28.0, ..NetParams::default() };
    let t_shallow = allreduce_packet(vol, dp, hop_debt, &inter, &shallow, None);
    let t_deep = allreduce_packet(vol, dp, hop_debt, &inter, &deep, None);
    let t_late = allreduce_packet(vol, dp, hop_debt, &inter, &late_ecn, None);
    assert!(t_deep <= t_shallow, "deeper queues can't hurt: {t_deep:?} vs {t_shallow:?}");
    assert!(t_late <= t_shallow, "later ECN can't hurt: {t_late:?} vs {t_shallow:?}");
    let fair = vol.raw() * dp as f64 / inter.bandwidth + hop_debt.raw();
    assert!(
        t_shallow.raw() > fair,
        "incast above fluid fair share: {} vs {fair}",
        t_shallow.raw()
    );
}

/// The trace export the CLI ships: non-empty, structurally valid JSONL
/// whose queue names point at the inter-package fabric.
#[test]
fn cluster_packet_trace_is_valid_jsonl() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let cluster = ClusterConfig::try_new(
        package_hw(),
        4,
        2,
        2,
        InterPkgLink::preset(InterKind::Substrate),
    )
    .unwrap();
    let plan = ClusterPlan::build(
        &m,
        &cluster,
        Method::Hecaton,
        PlanOptions::default(),
        &PlanCache::new(),
    )
    .unwrap();
    let trace = plan.packet_trace();
    assert!(!trace.queues.is_empty(), "the fabric registers queues");
    assert!(!trace.samples.is_empty(), "flows park bytes in queues");
    let jsonl = trace.to_jsonl();
    let mut lines = 0usize;
    for line in jsonl.lines() {
        lines += 1;
        assert!(line.starts_with("{\"t\":") && line.ends_with('}'), "{line}");
        for key in ["\"queue\":\"", "\"pkts\":", "\"dropped\":"] {
            assert!(line.contains(key), "{line} missing {key}");
        }
    }
    assert_eq!(lines, trace.samples.len(), "one JSON object per sample");
    assert!(
        trace.queues.iter().any(|q| q.contains("fabric")),
        "queues name the fabric links: {:?}",
        trace.queues
    );
}
