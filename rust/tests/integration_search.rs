//! Branch-and-bound design-space search — integration and property tests:
//!
//! * **admissible bounds** — the tier-0 (plan-free) and tier-1
//!   (plan-priced) lower bounds never exceed the true evaluated latency
//!   or energy, for every TP method × timing engine × topology on
//!   deterministic pseudo-random shapes, on packages and clusters alike;
//!   the closed-form SRAM floor never exceeds a real schedule's peak.
//! * **exhaustive equivalence** (the acceptance property) — on a
//!   512-point co-exploration grid the pruned search returns the
//!   bitwise-identical argmin and Pareto front the exhaustive
//!   `run_all` produces, fully evaluating at most 25% of the points,
//!   with the pruning ledger covering the grid exactly — and every
//!   count, index and value identical across thread counts.
//! * **feasibility cuts** — an enforced SRAM capacity below the weight
//!   floor makes the exhaustive sweep error while the search *counts*
//!   the whole grid as infeasible without building a single plan.
//! * **budgeted objective** — `latency-under-sram` reproduces the
//!   exhaustive argmin over the budget-satisfying subset, and a generous
//!   budget degenerates to the plain latency optimum.

use hecaton::prelude::*;
use hecaton::scenario;
use hecaton::search::{self, bound, Objective, SearchConfig};
use hecaton::sim::cluster::ClusterPlan;
use hecaton::util::Bytes;

/// Deterministic xorshift64 — property-test shapes without a rand
/// dependency (and reproducible failures).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[(self.next() % xs.len() as u64) as usize]
    }
}

/// Tier-0 and tier-1 bounds are admissible — `bound ≤ true cost` in both
/// coordinates — for every method × engine × topology on randomized
/// package shapes, and the SRAM floor is below every real peak.
#[test]
fn package_bounds_are_admissible_for_every_method_engine_topology() {
    let base = model_preset("tinyllama-1.1b").unwrap();
    let mut rng = Rng(0x9e37_79b9_7f4a_7c15);
    let cache = PlanCache::new();
    for method in Method::all() {
        for engine in [EngineKind::Analytic, EngineKind::Event, EngineKind::EventPrefetch] {
            for topo in [TopologyKind::Mesh2d, TopologyKind::Torus2d] {
                for _ in 0..3 {
                    let k = rng.pick(&[1usize, 2, 3]);
                    let (rows, cols) = rng.pick(&[(2usize, 2usize), (2, 4), (4, 4)]);
                    let dram = rng.pick(&[DramKind::Ddr5_6400, DramKind::Hbm2]);
                    let ck = rng.pick(&[Checkpoint::None, Checkpoint::Auto]);
                    let s = Scenario::builder(base.scaled(k))
                        .mesh(rows, cols)
                        .topology(topo)
                        .dram(dram)
                        .checkpoint(ck)
                        .method(method)
                        .engine(engine)
                        .build()
                        .unwrap();
                    let ev = evaluate(&s).unwrap();
                    let (lat, en) = (ev.latency().raw(), ev.energy_total().raw());
                    let lb0 = bound::tier0(&s);
                    let plan = cache.plan(&s.model, s.hw(), s.method, s.opts);
                    let lb1 = bound::tier1_package(&plan, s.hw(), lb0);
                    let tag = format!("{method:?}/{engine:?}/{topo:?} k={k} {rows}x{cols}");
                    for (tier, lb) in [("tier0", lb0), ("tier1", lb1)] {
                        assert!(
                            lb.latency_s <= lat,
                            "{tag} {tier}: latency bound {} > true {lat}",
                            lb.latency_s
                        );
                        assert!(
                            lb.energy_j <= en,
                            "{tag} {tier}: energy bound {} > true {en}",
                            lb.energy_j
                        );
                    }
                    assert!(
                        bound::sram_floor(&s.model, s.hw()).raw()
                            <= plan.occupancy.peak.raw() * (1.0 + 1e-9),
                        "{tag}: SRAM floor above a real schedule's peak"
                    );
                }
            }
        }
    }
}

/// The cluster tier-1 bound (critical-stage floor + per-stage dynamic
/// energy) is admissible for every method.
#[test]
fn cluster_bounds_are_admissible() {
    let model = model_preset("tinyllama-1.1b").unwrap();
    let cache = PlanCache::new();
    for method in Method::all() {
        let s = Scenario::builder(model.clone())
            .dies(16)
            .cluster(4, 2, 2)
            .method(method)
            .build()
            .unwrap();
        let ev = evaluate(&s).unwrap();
        let lb0 = bound::tier0(&s);
        let plan =
            ClusterPlan::build(&s.model, s.cluster_config().unwrap(), s.method, s.opts, &cache)
                .unwrap();
        let lb1 = bound::tier1_cluster(&plan, lb0);
        for (tier, lb) in [("tier0", lb0), ("tier1", lb1)] {
            assert!(
                lb.latency_s <= ev.latency().raw(),
                "{method:?} {tier}: latency bound above the cluster's true latency"
            );
            assert!(
                lb.energy_j <= ev.energy_total().raw(),
                "{method:?} {tier}: energy bound above the cluster's true energy"
            );
        }
    }
}

/// The acceptance grid: 8 model scales × 2 meshes × 2 topologies × 4
/// methods × 2 checkpoint policies = 512 points. Scaled models separate
/// the compute floors (≈k²), so the bound ordering has real teeth.
fn equivalence_grid() -> ScenarioGrid {
    let base = model_preset("tinyllama-1.1b").unwrap();
    ScenarioGrid {
        models: [1usize, 2, 3, 4, 6, 8, 12, 16]
            .iter()
            .map(|&k| base.scaled(k))
            .collect(),
        meshes: vec![(2, 4), (4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        topos: vec![TopologyKind::Mesh2d, TopologyKind::Torus2d],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic],
        checkpoints: vec![Checkpoint::None, Checkpoint::Auto],
        ..Default::default()
    }
}

fn search_on(grid: &ScenarioGrid, objective: Objective, threads: usize) -> search::SearchOutcome {
    let cfg = SearchConfig {
        threads,
        ..SearchConfig::new(objective)
    };
    search::run(grid, &cfg, &PlanCache::new()).unwrap()
}

/// Acceptance: on a ≥500-point grid, the pruned search returns the
/// bitwise-identical argmin the exhaustive sweep produces while fully
/// evaluating ≤ 25% of the points — with identical results *and counts*
/// across thread counts, and the ledger covering the grid exactly.
#[test]
fn pruned_latency_search_matches_exhaustive_on_512_points() {
    let grid = equivalence_grid();
    let (points, skipped) = grid.points().unwrap();
    assert!(points.len() >= 500, "acceptance grid must be ≥500 points");
    assert_eq!(skipped, 0);
    let evals = scenario::run_all(&points).unwrap();
    let mut best: Option<(f64, usize)> = None;
    for (i, ev) in evals.iter().enumerate() {
        let v = ev.latency().raw();
        if ev.feasible() && best.map_or(true, |(bv, _)| v < bv) {
            best = Some((v, i));
        }
    }
    let (bv, bi) = best.unwrap();

    let reference = search_on(&grid, Objective::Latency, 1);
    for threads in [1usize, 2, 4] {
        let out = search_on(&grid, Objective::Latency, threads);
        assert_eq!(out.total, points.len());
        assert_eq!(
            out.evaluated + out.pruned_bound + out.pruned_infeasible,
            out.total,
            "ledger must cover every point"
        );
        assert!(
            out.evaluated * 4 <= out.total,
            "must fully evaluate ≤ 25% of points: {} of {}",
            out.evaluated,
            out.total
        );
        assert_eq!(out.hits.len(), 1);
        assert_eq!(out.hits[0].index, bi, "threads={threads}");
        assert_eq!(
            out.hits[0].eval.latency().raw().to_bits(),
            bv.to_bits(),
            "threads={threads}: optimum must be bitwise-equal to the sweep's"
        );
        // Every count is part of the determinism contract.
        assert_eq!(out.evaluated, reference.evaluated, "threads={threads}");
        assert_eq!(out.pruned_bound, reference.pruned_bound, "threads={threads}");
        assert_eq!(out.pruned_infeasible, reference.pruned_infeasible, "threads={threads}");
        assert_eq!(out.groups, reference.groups, "threads={threads}");
        // The ledger is part of the rendered output.
        let table = search::render(&out, "table").unwrap();
        assert!(table.contains(&out.counts_line()), "{table}");
    }
}

/// Acceptance, Pareto flavor: identical front (same grid indices, same
/// bits) as annotating the exhaustive sweep, ≤ 25% evaluated, identical
/// across thread counts.
#[test]
fn pruned_pareto_search_matches_exhaustive_front() {
    let grid = equivalence_grid();
    let (points, _) = grid.points().unwrap();
    let evals = scenario::run_all(&points).unwrap();
    let want: Vec<(usize, u64, u64)> = scenario::pareto(&evals)
        .into_iter()
        .enumerate()
        .filter_map(|(i, on)| {
            on.then(|| {
                (
                    i,
                    evals[i].latency().raw().to_bits(),
                    evals[i].energy_total().raw().to_bits(),
                )
            })
        })
        .collect();
    assert!(!want.is_empty());

    let mut seen: Option<Vec<(usize, u64, u64)>> = None;
    for threads in [1usize, 4] {
        let out = search_on(&grid, Objective::Pareto, threads);
        assert!(
            out.evaluated * 4 <= out.total,
            "must fully evaluate ≤ 25% of points: {} of {}",
            out.evaluated,
            out.total
        );
        let got: Vec<(usize, u64, u64)> = out
            .hits
            .iter()
            .map(|h| {
                (
                    h.index,
                    h.eval.latency().raw().to_bits(),
                    h.eval.energy_total().raw().to_bits(),
                )
            })
            .collect();
        assert_eq!(got, want, "threads={threads}: front must match the sweep's");
        if let Some(prev) = &seen {
            assert_eq!(&got, prev, "front must not depend on thread count");
        }
        seen = Some(got);
    }
}

/// Plan-group sharing across timing engines: with three engines on
/// otherwise identical axes, the groups collapse 3:1 and the argmin still
/// matches the exhaustive sweep (which times every engine).
#[test]
fn engine_axis_shares_plan_groups() {
    let base = model_preset("tinyllama-1.1b").unwrap();
    let grid = ScenarioGrid {
        models: vec![base.scaled(1), base.scaled(2)],
        meshes: vec![(2, 2)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        topos: vec![TopologyKind::Mesh2d, TopologyKind::Torus2d],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic, EngineKind::Event, EngineKind::EventPrefetch],
        ..Default::default()
    };
    let (points, _) = grid.points().unwrap();
    let evals = scenario::run_all(&points).unwrap();
    let (bi, _) = evals
        .iter()
        .enumerate()
        .map(|(i, ev)| (i, ev.latency().raw()))
        .fold(None::<(usize, f64)>, |best, (i, v)| match best {
            Some((_, bv)) if bv <= v => best,
            _ => Some((i, v)),
        })
        .unwrap();
    for threads in [1usize, 3] {
        let out = search_on(&grid, Objective::Latency, threads);
        assert_eq!(out.groups * 3, out.total, "3 engines per plan group");
        assert_eq!(out.hits[0].index, bi, "threads={threads}");
    }
}

/// The pre-plan SRAM floor: a capacity below even the leanest schedule's
/// weight share makes the exhaustive sweep refuse to evaluate, while the
/// search counts every point infeasible without planning anything.
#[test]
fn sram_floor_cuts_grids_the_sweep_refuses()  {
    let grid = ScenarioGrid {
        models: vec![model_preset("tinyllama-1.1b").unwrap()],
        meshes: vec![(2, 2), (4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        sram: vec![Some(Bytes::mib(0.25))],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic],
        ..Default::default()
    };
    let (points, _) = grid.points().unwrap();
    assert!(
        scenario::run_all(&points).is_err(),
        "the exhaustive sweep errors on enforced-infeasible points"
    );
    let out = search_on(&grid, Objective::Latency, 1);
    assert_eq!(out.pruned_infeasible, out.total);
    assert_eq!(out.evaluated, 0);
    assert_eq!(out.pruned_bound, 0);
    assert!(out.hits.is_empty());
    let table = search::render(&out, "table").unwrap();
    assert!(table.contains("no feasible point"), "{table}");
}

/// `latency-under-sram`: a tight budget reproduces the exhaustive argmin
/// over the budget-satisfying subset (same tolerance rule as the
/// occupancy report), and a generous budget degenerates to the plain
/// latency optimum.
#[test]
fn budgeted_objective_matches_filtered_argmin() {
    let grid = equivalence_grid();
    let (points, _) = grid.points().unwrap();
    let evals = scenario::run_all(&points).unwrap();
    let peaks: Vec<f64> = evals.iter().map(|e| e.sim().occupancy.peak.raw()).collect();
    // A budget just above the leanest schedule: a genuinely selective cut.
    let min_peak = peaks.iter().cloned().fold(f64::INFINITY, f64::min);
    let budget = Bytes(min_peak * 1.2);
    let mut best: Option<(f64, usize)> = None;
    for (i, ev) in evals.iter().enumerate() {
        if peaks[i] > budget.raw() * (1.0 + 1e-9) || !ev.feasible() {
            continue;
        }
        let v = ev.latency().raw();
        if best.map_or(true, |(bv, _)| v < bv) {
            best = Some((v, i));
        }
    }
    let (bv, bi) = best.expect("some point fits a 1.2x-min budget");

    let out = search_on(&grid, Objective::LatencyUnderSram(budget), 2);
    assert_eq!(out.hits.len(), 1);
    assert_eq!(out.hits[0].index, bi);
    assert_eq!(out.hits[0].eval.latency().raw().to_bits(), bv.to_bits());
    assert!(out.pruned_infeasible > 0, "a tight budget must cut points");

    // Generous budget: bitwise the plain latency optimum.
    let plain = search_on(&grid, Objective::Latency, 2);
    let roomy = search_on(&grid, Objective::LatencyUnderSram(Bytes::gib(1024.0)), 2);
    assert_eq!(roomy.hits[0].index, plain.hits[0].index);
    assert_eq!(
        roomy.hits[0].eval.latency().raw().to_bits(),
        plain.hits[0].eval.latency().raw().to_bits()
    );
}
