//! Topology-axis integration tests — the comm-IR refactor's acceptance:
//!
//! * **Mesh parity** — evaluating with the topology axis *named* (the
//!   default 2D mesh, the default substrate fabric) is bitwise-identical
//!   to leaving it defaulted, for all four TP methods and all three
//!   engines. Together with the unit-level property tests in
//!   `src/comm/mod.rs` (IR lowering == legacy schedule builders, bitwise)
//!   this pins every pre-IR result through the new layer.
//! * **Engine parity on the new topologies** — event vs analytic timing
//!   agrees ≤1% on the torus NoP and on the fat-tree inter-package
//!   fabric, the same bar the mesh/substrate stack already meets.
//! * **Ordering** — torus wrap links never price a collective above its
//!   mesh lowering, end to end.

use hecaton::config::cluster::{ClusterConfig, InterKind, InterPkgLink};
use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, PackageKind, TopologyKind};
use hecaton::nop::analytic::Method;
use hecaton::scenario::Scenario;
use hecaton::sim::cluster::ClusterPlan;
use hecaton::sim::sweep::PlanCache;
use hecaton::sim::system::{EngineKind, PlanOptions};
use hecaton::util::Seconds;

fn package_scenario(method: Method, engine: EngineKind, topo: Option<TopologyKind>) -> Scenario {
    let b = Scenario::builder(model_preset("tinyllama-1.1b").unwrap())
        .dies(16)
        .method(method)
        .engine(engine);
    let b = match topo {
        Some(t) => b.topology(t),
        None => b,
    };
    b.build().unwrap()
}

/// Naming the default topology must not perturb a single bit: the IR is
/// the only pricing path, so `--topo mesh` and the pre-axis default are
/// the same evaluation for every method × engine on the substrate stack.
#[test]
fn explicit_mesh_is_bitwise_the_default_for_every_method_and_engine() {
    let cache = PlanCache::new();
    for method in Method::all() {
        for engine in EngineKind::all() {
            let base = package_scenario(method, engine, None);
            let named = package_scenario(method, engine, Some(TopologyKind::Mesh2d));
            let a = base.evaluate_on(&cache).unwrap();
            let b = named.evaluate_on(&cache).unwrap();
            let tag = format!("{method:?}/{engine:?}");
            assert_eq!(
                a.latency().raw().to_bits(),
                b.latency().raw().to_bits(),
                "{tag}: latency"
            );
            assert_eq!(
                a.energy_total().raw().to_bits(),
                b.energy_total().raw().to_bits(),
                "{tag}: energy"
            );
        }
    }
}

/// Same invariant on the cluster path: naming the substrate fabric (the
/// point-to-point default) changes nothing, across engines.
#[test]
fn explicit_substrate_cluster_is_bitwise_the_default() {
    let cache = PlanCache::new();
    for engine in EngineKind::all() {
        let mk = |named: bool| {
            let b = Scenario::builder(model_preset("tinyllama-1.1b").unwrap())
                .dies(16)
                .cluster(4, 2, 2)
                .engine(engine);
            let b = if named {
                b.inter(InterPkgLink::preset(InterKind::Substrate))
                    .topology(TopologyKind::Mesh2d)
            } else {
                b
            };
            b.build().unwrap()
        };
        let a = mk(false).evaluate_on(&cache).unwrap();
        let b = mk(true).evaluate_on(&cache).unwrap();
        assert_eq!(
            a.latency().raw().to_bits(),
            b.latency().raw().to_bits(),
            "{engine:?}: latency"
        );
        assert_eq!(
            a.energy_total().raw().to_bits(),
            b.energy_total().raw().to_bits(),
            "{engine:?}: energy"
        );
    }
}

/// Event vs analytic timing on the torus NoP meets the same ≤1% bar the
/// mesh stack does, for every TP method.
#[test]
fn torus_engines_agree_within_one_percent() {
    let cache = PlanCache::new();
    for method in Method::all() {
        let a = package_scenario(method, EngineKind::Analytic, Some(TopologyKind::Torus2d))
            .evaluate_on(&cache)
            .unwrap();
        for engine in [EngineKind::Event, EngineKind::EventPrefetch] {
            let e = package_scenario(method, engine, Some(TopologyKind::Torus2d))
                .evaluate_on(&cache)
                .unwrap();
            let (ar, er) = (a.latency().raw(), e.latency().raw());
            assert!(
                ((er - ar) / ar).abs() <= 1e-2,
                "{method:?}/{engine:?}: event {er} vs analytic {ar}"
            );
        }
    }
}

/// The torus lowering never prices a run above its mesh twin: wrap links
/// only shorten hops (bytes on the wire are identical by construction).
#[test]
fn torus_never_loses_to_mesh_end_to_end() {
    let cache = PlanCache::new();
    for method in Method::all() {
        let mesh = package_scenario(method, EngineKind::Analytic, Some(TopologyKind::Mesh2d))
            .evaluate_on(&cache)
            .unwrap();
        let torus = package_scenario(method, EngineKind::Analytic, Some(TopologyKind::Torus2d))
            .evaluate_on(&cache)
            .unwrap();
        assert!(
            torus.latency().raw() <= mesh.latency().raw() * (1.0 + 1e-12),
            "{method:?}: torus {} vs mesh {}",
            torus.latency(),
            mesh.latency()
        );
    }
}

/// Event vs analytic cluster timing agrees ≤1% on an uncongested
/// fat-tree fabric (mirroring the point-to-point parity test in
/// `integration_cluster.rs`), across dp/pp shapes.
#[test]
fn fat_tree_cluster_engines_agree_within_one_percent() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let cache = PlanCache::new();
    let mut ft = InterPkgLink::preset(InterKind::FatTree);
    ft.bandwidth = 1.0e15;
    ft.latency = Seconds::ns(1.0);
    for (dp, pp) in [(4usize, 1usize), (2, 2), (1, 4)] {
        let cluster = ClusterConfig::try_new(hw.clone(), dp * pp, dp, pp, ft.clone()).unwrap();
        let plan =
            ClusterPlan::build(&m, &cluster, Method::Hecaton, PlanOptions::default(), &cache)
                .unwrap();
        let a = plan.time(EngineKind::Analytic);
        for engine in [EngineKind::Event, EngineKind::EventPrefetch] {
            let e = plan.time(engine);
            let (ar, er) = (a.latency.raw(), e.latency.raw());
            assert!(
                ((er - ar) / ar).abs() <= 1e-2,
                "dp{dp}xpp{pp}/{engine:?}: event {er} vs analytic {ar}"
            );
        }
    }
}
