//! Integration tests over the full simulator stack: config → workload →
//! parallel planners → scheduler → system simulator → reports.

use hecaton::config::presets::{eval_models, model_preset, paper_pairings};
use hecaton::config::{DramKind, HardwareConfig, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::sim::system::simulate;

/// Every evaluation model simulates under every method on a mid-size mesh
/// without panicking, and produces internally-consistent results.
#[test]
fn full_grid_is_well_formed() {
    for name in eval_models() {
        let model = model_preset(name).unwrap();
        for package in [PackageKind::Standard, PackageKind::Advanced] {
            let hw = HardwareConfig::square(64, package, DramKind::Ddr5_6400);
            for method in Method::all() {
                let r = simulate(&model, &hw, method);
                assert!(r.latency.raw() > 0.0, "{name}/{method:?}");
                assert!(r.energy_total.raw() > 0.0);
                assert!(r.total_macs > 0.0);
                assert!(r.min_utilization > 0.0 && r.min_utilization <= 1.0);
                // Breakdown components sum to the latency (2% slack for
                // pipeline fill accounting).
                let sum = r.breakdown.total().raw();
                assert!(
                    (sum - r.latency.raw()).abs() / r.latency.raw() < 0.02,
                    "{name}/{method:?}: {sum} vs {}",
                    r.latency.raw()
                );
            }
        }
    }
}

/// The same model on the same mesh: more link bandwidth never hurts, more
/// DRAM bandwidth never hurts, bigger buffers never hurt.
#[test]
fn monotonicity_in_resources() {
    let model = model_preset("llama2-7b").unwrap();
    let base = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let r_base = simulate(&model, &base, Method::Hecaton);

    let mut fat_link = base.clone();
    fat_link.link.bandwidth *= 4.0;
    assert!(simulate(&model, &fat_link, Method::Hecaton).latency <= r_base.latency);

    let hbm = base.clone().with_dram(DramKind::Hbm2);
    assert!(simulate(&model, &hbm, Method::Hecaton).latency <= r_base.latency);

    let mut big_buf = base.clone();
    big_buf.die.weight_buf = big_buf.die.weight_buf * 4.0;
    big_buf.die.act_buf = big_buf.die.act_buf * 4.0;
    assert!(simulate(&model, &big_buf, Method::Hecaton).latency <= r_base.latency * 1.001);
}

/// MAC conservation: all four methods execute the same total MACs for the
/// same workload (within ceil-induced padding).
#[test]
fn methods_agree_on_total_macs() {
    let model = model_preset("gpt3-6.7b").unwrap();
    let hw = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let macs: Vec<f64> = Method::all()
        .iter()
        .map(|&m| simulate(&model, &hw, m).total_macs)
        .collect();
    for m in &macs {
        assert!(
            (m / macs[0] - 1.0).abs() < 0.05,
            "MAC counts diverge: {macs:?}"
        );
    }
}

/// The paper's scaling pairings all run at full scale (1024 dies) within
/// reasonable wall-time — guards against accidental quadratic blowups in
/// the planner/simulator.
#[test]
fn full_scale_sweep_is_fast() {
    let t0 = std::time::Instant::now();
    for w in paper_pairings() {
        let hw = HardwareConfig::square(w.dies, PackageKind::Advanced, DramKind::Ddr5_6400);
        for m in Method::all() {
            let _ = simulate(&w.model, &hw, m);
        }
    }
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "sweep took {:?}",
        t0.elapsed()
    );
}

/// Reports render for every experiment id.
#[test]
fn all_reports_render() {
    for id in hecaton::report::experiments() {
        let out = hecaton::report::run(id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert!(out.len() > 100, "{id} report suspiciously short");
    }
    assert!(hecaton::report::run("nope").is_err());
}
