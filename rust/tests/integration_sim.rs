//! Integration tests over the full simulator stack: config → workload →
//! parallel planners → scheduler → system simulator → reports.

use hecaton::config::presets::{eval_models, model_preset, paper_pairings};
use hecaton::config::{DramKind, HardwareConfig, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::sim::sweep::{
    pareto_front, run_points_on, run_points_threads, PlanCache, SweepPoint,
};
use hecaton::sim::system::{simulate, simulate_engine, EngineKind, SimResult};

/// Every evaluation model simulates under every method on a mid-size mesh
/// without panicking, and produces internally-consistent results.
#[test]
fn full_grid_is_well_formed() {
    for name in eval_models() {
        let model = model_preset(name).unwrap();
        for package in [PackageKind::Standard, PackageKind::Advanced] {
            let hw = HardwareConfig::square(64, package, DramKind::Ddr5_6400);
            for method in Method::all() {
                let r = simulate(&model, &hw, method);
                assert!(r.latency.raw() > 0.0, "{name}/{method:?}");
                assert!(r.energy_total.raw() > 0.0);
                assert!(r.total_macs > 0.0);
                let min_util = r.min_utilization.expect("real workloads record utilization");
                assert!(min_util > 0.0 && min_util <= 1.0);
                // Breakdown components sum to the latency (2% slack for
                // pipeline fill accounting).
                let sum = r.breakdown.total().raw();
                assert!(
                    (sum - r.latency.raw()).abs() / r.latency.raw() < 0.02,
                    "{name}/{method:?}: {sum} vs {}",
                    r.latency.raw()
                );
            }
        }
    }
}

/// The same model on the same mesh: more link bandwidth never hurts, more
/// DRAM bandwidth never hurts, bigger buffers never hurt.
#[test]
fn monotonicity_in_resources() {
    let model = model_preset("llama2-7b").unwrap();
    let base = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let r_base = simulate(&model, &base, Method::Hecaton);

    let mut fat_link = base.clone();
    fat_link.link.bandwidth *= 4.0;
    assert!(simulate(&model, &fat_link, Method::Hecaton).latency <= r_base.latency);

    let hbm = base.clone().with_dram(DramKind::Hbm2);
    assert!(simulate(&model, &hbm, Method::Hecaton).latency <= r_base.latency);

    let mut big_buf = base.clone();
    big_buf.die.weight_buf = big_buf.die.weight_buf * 4.0;
    big_buf.die.act_buf = big_buf.die.act_buf * 4.0;
    assert!(simulate(&model, &big_buf, Method::Hecaton).latency <= r_base.latency * 1.001);
}

/// MAC conservation: all four methods execute the same total MACs for the
/// same workload (within ceil-induced padding).
#[test]
fn methods_agree_on_total_macs() {
    let model = model_preset("gpt3-6.7b").unwrap();
    let hw = HardwareConfig::square(64, PackageKind::Standard, DramKind::Ddr5_6400);
    let macs: Vec<f64> = Method::all()
        .iter()
        .map(|&m| simulate(&model, &hw, m).total_macs)
        .collect();
    for m in &macs {
        assert!(
            (m / macs[0] - 1.0).abs() < 0.05,
            "MAC counts diverge: {macs:?}"
        );
    }
}

/// The paper's scaling pairings all run at full scale (1024 dies) within
/// reasonable wall-time — guards against accidental quadratic blowups in
/// the planner/simulator.
#[test]
fn full_scale_sweep_is_fast() {
    let t0 = std::time::Instant::now();
    for w in paper_pairings() {
        let hw = HardwareConfig::square(w.dies, PackageKind::Advanced, DramKind::Ddr5_6400);
        for m in Method::all() {
            let _ = simulate(&w.model, &hw, m);
        }
    }
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "sweep took {:?}",
        t0.elapsed()
    );
}

/// Reports render for every experiment id — the golden-shape guard for
/// the sweep-runner refactor of the report drivers: every driver now runs
/// its grid through `sim::sweep` and must keep producing its rows.
#[test]
fn all_reports_render() {
    for id in hecaton::report::experiments() {
        let out = hecaton::report::run(id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert!(out.len() > 100, "{id} report suspiciously short");
    }
    assert!(hecaton::report::run("nope").is_err());
}

// ───────────────────────── sweep subsystem ─────────────────────────

fn assert_bitwise_eq(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(a.method, b.method, "{ctx}: method");
    assert_eq!(a.engine, b.engine, "{ctx}: engine");
    assert_eq!(
        a.latency.raw().to_bits(),
        b.latency.raw().to_bits(),
        "{ctx}: latency"
    );
    assert_eq!(
        a.energy_total.raw().to_bits(),
        b.energy_total.raw().to_bits(),
        "{ctx}: energy"
    );
    assert_eq!(a.breakdown, b.breakdown, "{ctx}: breakdown");
    assert_eq!(a.energy, b.energy, "{ctx}: energy breakdown");
    assert_eq!(a.min_utilization, b.min_utilization, "{ctx}: min_utilization");
    assert_eq!(a.fusion_groups, b.fusion_groups, "{ctx}: fusion groups");
    assert_eq!(a.n_minibatches, b.n_minibatches, "{ctx}: n_minibatches");
    assert_eq!(
        a.dram_bytes.raw().to_bits(),
        b.dram_bytes.raw().to_bits(),
        "{ctx}: dram bytes"
    );
    assert_eq!(a.total_macs.to_bits(), b.total_macs.to_bits(), "{ctx}: macs");
}

/// The old SweepGrid test grid, expanded by hand (grid expansion itself
/// is covered by `scenario::ScenarioGrid`'s tests): 2 models × 2 meshes ×
/// 4 methods × 2 engines.
fn test_grid() -> Vec<SweepPoint> {
    let models = [
        model_preset("tinyllama-1.1b").unwrap(),
        model_preset("llama2-7b").unwrap(),
    ];
    let mut pts = Vec::new();
    for model in &models {
        for (rows, cols) in [(4usize, 4usize), (2, 8)] {
            let hw = HardwareConfig::mesh(rows, cols, PackageKind::Standard, DramKind::Ddr5_6400);
            for method in Method::all() {
                for engine in [EngineKind::Analytic, EngineKind::Event] {
                    pts.push(SweepPoint::new(model.clone(), hw.clone(), method, engine));
                }
            }
        }
    }
    pts
}

/// Parallel sweep output is byte-identical to serial execution and
/// independent of the worker count.
#[test]
fn parallel_sweep_is_bitwise_deterministic() {
    let points = test_grid();
    let serial = run_points_threads(&points, 1);
    assert_eq!(serial.len(), points.len());
    for threads in [2, 3, 8] {
        let parallel = run_points_threads(&points, threads);
        assert_eq!(parallel.len(), serial.len());
        for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
            assert_bitwise_eq(s, p, &format!("threads={threads} point={i}"));
        }
    }
}

/// A plan-cache hit produces a `SimResult` byte-identical to a cold run
/// (and to the plain `simulate_engine` path).
#[test]
fn plan_cache_hit_matches_cold_run() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let points: Vec<SweepPoint> = EngineKind::all()
        .into_iter()
        .map(|e| SweepPoint::new(m.clone(), hw.clone(), Method::Hecaton, e))
        .collect();

    let cache = PlanCache::new();
    let cold = run_points_on(&cache, &points, 1);
    assert_eq!(cache.misses(), 1, "one plan serves all engines");
    assert_eq!(cache.hits(), EngineKind::all().len() - 1);
    let warm = run_points_on(&cache, &points, 1);
    assert_eq!(cache.misses(), 1, "warm pass builds nothing");
    for (i, (c, w)) in cold.iter().zip(&warm).enumerate() {
        assert_bitwise_eq(c, w, &format!("warm point={i}"));
    }
    for (p, c) in points.iter().zip(&cold) {
        let direct = simulate_engine(&p.model, &p.hw, p.method, p.opts.engine);
        assert_bitwise_eq(c, &direct, "cached vs direct");
    }
}

/// The sweep's Pareto annotation: on the Fig. 8-style method grid, every
/// feasible-and-fastest point must sit on the latency × energy frontier,
/// and at least one point is always on it.
#[test]
fn sweep_pareto_annotation_is_consistent() {
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let points: Vec<SweepPoint> = Method::all()
        .into_iter()
        .map(|method| SweepPoint::new(m.clone(), hw.clone(), method, EngineKind::Analytic))
        .collect();
    let results = run_points_threads(&points, 2);
    let metrics: Vec<(f64, f64)> = results
        .iter()
        .map(|r| (r.latency.raw(), r.energy_total.raw()))
        .collect();
    let front = pareto_front(&metrics);
    assert!(front.iter().any(|&b| b), "frontier can't be empty");
    // A frontier point is not dominated by any other result.
    for (i, &on) in front.iter().enumerate() {
        let dominated = metrics.iter().enumerate().any(|(j, &(l, e))| {
            j != i
                && l <= metrics[i].0
                && e <= metrics[i].1
                && (l < metrics[i].0 || e < metrics[i].1)
        });
        assert_eq!(on, !dominated, "point {i}");
    }
}

/// The refactored report drivers keep their golden shapes: fig8's grid
/// still normalizes Hecaton rows to exactly 1.0 and row counts are
/// unchanged (the drivers now execute on the parallel sweep runner).
#[test]
fn refactored_drivers_keep_golden_shapes() {
    let cells = hecaton::report::fig8::run();
    assert_eq!(cells.len(), 2 * 4 * 4);
    for c in cells.iter().filter(|c| c.method == Method::Hecaton) {
        assert!((c.rel_latency - 1.0).abs() < 1e-12);
        assert!((c.rel_energy - 1.0).abs() < 1e-12);
    }
    // And each fig8 cell matches a direct (serial) simulation bitwise.
    let w = &paper_pairings()[0];
    let hw = HardwareConfig::square(w.dies, PackageKind::Standard, DramKind::Ddr5_6400);
    let direct = simulate(&w.model, &hw, Method::Hecaton);
    let cell = cells
        .iter()
        .find(|c| {
            c.model == w.model.name
                && c.package == PackageKind::Standard
                && c.method == Method::Hecaton
        })
        .unwrap();
    assert_bitwise_eq(&cell.result, &direct, "fig8 vs direct");
}
