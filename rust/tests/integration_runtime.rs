//! Integration tests over the runtime + coordinator: failure injection
//! and cross-layer contracts.

use hecaton::coordinator::{coord_model, Coordinator, MeshCfg};
use hecaton::runtime::{Manifest, Runtime, Tensor};

fn artifacts_ready() -> bool {
    hecaton::runtime::artifact_dir().join("manifest.txt").exists()
}

/// A missing artifact directory is a clean error, not a panic.
#[test]
fn missing_artifact_dir_reports_cleanly() {
    let Err(err) = Runtime::open(std::path::PathBuf::from("/nonexistent/path")) else {
        panic!("opening a missing dir must fail");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

/// A corrupt HLO file is a compile-time error surfaced with the artifact
/// name, and does not poison the runtime for other artifacts.
#[test]
fn corrupt_artifact_is_isolated() {
    if !artifacts_ready() {
        return;
    }
    let dir = tempdir();
    std::fs::write(dir.join("manifest.txt"), "broken_2x2 2x2:float32\n").unwrap();
    std::fs::write(dir.join("broken_2x2.hlo.txt"), "this is not HLO").unwrap();
    let rt = Runtime::open(dir.clone()).unwrap();
    let x = Tensor::zeros(&[2, 2]);
    let err = rt.exec("broken_2x2", &[x.into()]).unwrap_err();
    assert!(format!("{err:#}").contains("broken_2x2"));
    std::fs::remove_dir_all(dir).ok();
}

/// Manifest round-trip: every artifact the coordinator's tiny@2x2 mesh
/// will request is present with the expected arity.
#[test]
fn manifest_covers_coordinator_contract() {
    if !artifacts_ready() {
        return;
    }
    let m = Manifest::load(&hecaton::runtime::artifact_dir()).unwrap();
    // All tile matmuls of tiny@2x2 (pinned in python tests too).
    for name in [
        "matmul_64x32x96",
        "matmul_64x96x32",
        "matmul_32x64x96",
        "matmul_64x32x32",
        "matmul_32x64x32",
        "matmul_64x32x128",
        "matmul_64x128x32",
        "matmul_32x64x128",
        "matmul_128x64x32",
        "matmul_64x64x64",
        "attention_fwd_2x32x16",
        "attention_bwd_2x32x16",
        "rmsnorm_fwd_64x64",
        "rmsnorm_bwd_64x64",
        "gelu_fwd_32x128",
        "gelu_bwd_32x128",
        "xent_64x64",
    ] {
        assert!(m.contains(name), "missing artifact {name}");
    }
    for (name, arity) in [("matmul_64x32x96", 2), ("attention_bwd_2x32x16", 4), ("rmsnorm_bwd_64x64", 3)] {
        assert_eq!(m.get(name).unwrap().inputs.len(), arity, "{name}");
    }
}

/// Wrong-sized mini-batches are rejected before any die work happens.
#[test]
fn coordinator_rejects_bad_minibatch() {
    if !artifacts_ready() {
        return;
    }
    let cfg = MeshCfg::new(coord_model("tiny").unwrap(), 2, 2, 64);
    let mut coord = Coordinator::new(cfg, 1).unwrap();
    let tokens = vec![0u32; 32]; // must be 64
    let targets = vec![0i32; 32];
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        coord.grad_step(&tokens, &targets)
    }));
    assert!(r.is_err(), "short mini-batch must be rejected");
    coord.shutdown().ok();
}

/// Two coordinators with the same seed produce identical first losses
/// (deterministic init + deterministic schedule).
#[test]
fn coordinator_is_deterministic() {
    if !artifacts_ready() {
        return;
    }
    let loss = |seed| {
        let cfg = MeshCfg::new(coord_model("tiny").unwrap(), 2, 2, 64);
        let mut c = Coordinator::new(cfg, seed).unwrap();
        let tokens: Vec<u32> = (0..64).map(|i| (i % 64) as u32).collect();
        let targets: Vec<i32> = (0..64).map(|i| ((i + 1) % 64) as i32).collect();
        let l = c.grad_step(&tokens, &targets).unwrap();
        c.shutdown().unwrap();
        l
    };
    assert_eq!(loss(5), loss(5));
    assert_ne!(loss(5), loss(6)); // different init → different loss
}

fn tempdir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hecaton-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}
