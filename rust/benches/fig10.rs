//! Bench + regeneration of paper Fig. 10 (DRAM bandwidth sweep).
mod common;

fn main() {
    println!("{}", hecaton::report::run("fig10").expect("fig10"));
    let mut b = common::Bench::new("fig10");
    b.bench("fig10/dram_sweep", || {
        common::black_box(hecaton::report::fig10::run());
    });
    b.finish();
}
