//! Bench + regeneration of paper Table IV (link-latency proportion) and
//! Table III (closed forms).
mod common;

fn main() {
    println!("{}", hecaton::report::run("table3").expect("table3"));
    println!("{}", hecaton::report::run("table4").expect("table4"));
    let mut b = common::Bench::new("table4");
    b.bench("table4/link_latency_sweep", || {
        common::black_box(hecaton::report::table4::run());
    });
    b.finish();
}
