//! Bench + regeneration of paper Fig. 9 (scaling study).
mod common;

fn main() {
    println!("{}", hecaton::report::run("fig9").expect("fig9"));
    let mut b = common::Bench::new("fig9");
    b.bench("fig9/scaling_sweep", || {
        common::black_box(hecaton::report::fig9::run());
    });
    b.finish();
}
