//! Bench + regeneration of §VI-G (energy efficiency vs A100 cluster).
mod common;

fn main() {
    println!("{}", hecaton::report::run("gpu").expect("gpu"));
    let mut b = common::Bench::new("gpu_compare");
    b.bench("gpu/comparison", || {
        common::black_box(hecaton::report::gpu::run());
    });
    b.finish();
}
