//! Bench + regeneration of paper Fig. 8 (overall latency/energy grid).
mod common;

fn main() {
    // Print the reproduced figure once.
    println!("{}", hecaton::report::run("fig8").expect("fig8"));
    // Then time the full grid (the fig8 sweep is itself a simulator
    // workload: 2 packages x 4 workloads x 4 methods).
    let mut b = common::Bench::new("fig8");
    b.bench("fig8/full_grid", || {
        common::black_box(hecaton::report::fig8::run());
    });
    b.finish();
}
