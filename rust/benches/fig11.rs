//! Bench + regeneration of paper Fig. 11 (layout sweep).
mod common;

fn main() {
    println!("{}", hecaton::report::run("fig11").expect("fig11"));
    let mut b = common::Bench::new("fig11");
    b.bench("fig11/layout_sweep", || {
        common::black_box(hecaton::report::fig11::run());
    });
    b.finish();
}
