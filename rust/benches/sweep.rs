//! Sweep-subsystem benchmarks: serial vs parallel wall-clock on the
//! Fig. 8 grid (the acceptance bar is ≥2× on a ≥4-core runner — compare
//! `sweep/fig8_grid_serial` vs `sweep/fig8_grid_parallel` in
//! `BENCH_sweep.json`), plan-cache effectiveness across engine backends,
//! and the O(n) fusion planner on the deepest paper chain.

mod common;

use hecaton::config::presets::{model_preset, paper_pairings};
use hecaton::config::{DramKind, HardwareConfig, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::parallel::plan::planner;
use hecaton::scenario::{run_on, Scenario};
use hecaton::sched::fusion::plan_fusion;
use hecaton::sim::sweep::{run_points_on, run_points_threads, PlanCache, SweepPoint};
use hecaton::sim::system::EngineKind;
use hecaton::workload::ops::BlockDesc;
use hecaton::workload::transformer::layer_blocks;

/// The Fig. 8 grid as a point list: 2 packages × 4 pairings × 4 methods.
fn fig8_points(engine: EngineKind) -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for package in [PackageKind::Standard, PackageKind::Advanced] {
        for w in paper_pairings() {
            let hw = HardwareConfig::square(w.dies, package, DramKind::Ddr5_6400);
            for method in Method::all() {
                points.push(SweepPoint::new(w.model.clone(), hw.clone(), method, engine));
            }
        }
    }
    points
}

fn main() {
    let mut b = common::Bench::new("sweep");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    eprintln!("(running on {cores} cores)");

    // ── serial vs parallel: the acceptance-bar pair ──
    let points = fig8_points(EngineKind::Analytic);
    b.bench("sweep/fig8_grid_serial", || {
        common::black_box(run_points_threads(&points, 1));
    });
    b.bench("sweep/fig8_grid_parallel", || {
        common::black_box(run_points_threads(&points, 0));
    });

    // ── scenario service path: the same grid through scenario::run_on,
    // which adds plan-affine execution order + per-worker EvalScratch
    // (arena + last-plan reuse) on top of the raw point runner ──
    let scenarios: Vec<Scenario> = {
        let mut out = Vec::new();
        for package in [PackageKind::Standard, PackageKind::Advanced] {
            for w in paper_pairings() {
                for method in Method::all() {
                    out.push(
                        Scenario::builder(w.model.clone())
                            .dies(w.dies)
                            .package(package)
                            .method(method)
                            .build()
                            .expect("paper pairing scenarios are valid"),
                    );
                }
            }
        }
        out
    };
    b.bench("sweep/fig8_scenarios_service", || {
        common::black_box(run_on(&PlanCache::new(), &scenarios, 0).expect("grid evaluates"));
    });

    // ── plan cache: all three engines over the parity mesh; cold vs a
    // pre-warmed cache (plans shared across engines and iterations) ──
    let m = model_preset("tinyllama-1.1b").unwrap();
    let hw = HardwareConfig::square(16, PackageKind::Standard, DramKind::Ddr5_6400);
    let engine_points: Vec<SweepPoint> = Method::all()
        .into_iter()
        .flat_map(|method| {
            EngineKind::all()
                .into_iter()
                .map(|e| SweepPoint::new(m.clone(), hw.clone(), method, e))
                .collect::<Vec<_>>()
        })
        .collect();
    b.bench("sweep/engines_x_methods_cold", || {
        common::black_box(run_points_threads(&engine_points, 1));
    });
    let warm = PlanCache::new();
    let _ = run_points_on(&warm, &engine_points, 1);
    b.bench("sweep/engines_x_methods_warm_cache", || {
        common::black_box(run_points_on(&warm, &engine_points, 1));
    });

    // ── fusion planner: O(n) guard on 405B's 252-block chain ──
    let model405 = model_preset("llama3.1-405b").unwrap();
    let hw1024 = HardwareConfig::square(1024, PackageKind::Standard, DramKind::Ddr5_6400);
    let chain405: Vec<BlockDesc> = (0..model405.layers)
        .flat_map(|_| layer_blocks(&model405))
        .collect();
    let hec = planner(Method::Hecaton);
    b.bench("sweep/plan_fusion_252blocks", || {
        common::black_box(plan_fusion(&chain405, hec.as_ref(), &hw1024));
    });

    b.finish_with_json("BENCH_sweep.json");
}
