//! Cluster-layer benchmarks: hybrid TP×DP×PP planning and timing on the
//! paper-scale presets, the 1F1B event DAG, and the cluster sweep runner.
//! Emits `BENCH_cluster.json` (CI artifact) so cluster-path perf is
//! tracked across commits like the engine and sweep suites.

mod common;

use hecaton::config::cluster::{cluster_preset, InterKind, InterPkgLink};
use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::scenario::{self, ScenarioGrid};
use hecaton::sim::cluster::ClusterPlan;
use hecaton::sim::sweep::PlanCache;
use hecaton::sim::system::{EngineKind, PlanOptions};

fn main() {
    let mut b = common::Bench::new("cluster");

    // ── plan + price: the 405B-class hybrid through a cold cache ──
    let (model405, cluster405) = cluster_preset("405b-cluster").expect("preset");
    b.bench("cluster/405b_plan_cold", || {
        let cache = PlanCache::new();
        common::black_box(
            ClusterPlan::build(
                &model405,
                &cluster405,
                Method::Hecaton,
                PlanOptions::default(),
                &cache,
            )
            .expect("preset is valid"),
        );
    });

    // ── time: analytic closed forms vs the 1F1B event DAG on one plan ──
    let cache = PlanCache::new();
    let plan = ClusterPlan::build(
        &model405,
        &cluster405,
        Method::Hecaton,
        PlanOptions::default(),
        &cache,
    )
    .expect("preset is valid");
    b.bench("cluster/405b_time_analytic", || {
        common::black_box(plan.time(EngineKind::Analytic));
    });
    b.bench("cluster/405b_time_event_1f1b", || {
        common::black_box(plan.time(EngineKind::Event));
    });

    // ── sweep: the tiny-cluster shape grid, serial vs parallel ──
    let grid = ScenarioGrid {
        models: vec![model_preset("tinyllama-1.1b").expect("preset")],
        meshes: vec![(4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic, EngineKind::Event],
        n_packages: vec![4],
        dp: vec![1, 2, 4],
        pp: vec![1, 2, 4],
        inter: vec![InterPkgLink::preset(InterKind::Substrate)],
        ..Default::default()
    };
    let (points, _) = grid.points().expect("grid expands");
    b.bench("cluster/shape_grid_serial", || {
        let r = scenario::run_on(&PlanCache::new(), &points, 1);
        common::black_box(r.expect("grid points are valid"));
    });
    b.bench("cluster/shape_grid_parallel", || {
        let r = scenario::run_on(&PlanCache::new(), &points, 0);
        common::black_box(r.expect("grid points are valid"));
    });

    b.finish_with_json("BENCH_cluster.json");
}
