//! Hot-path micro-benchmarks — the targets of the EXPERIMENTS.md §Perf
//! pass:
//!
//! * L3 simulator: one `simulate()` call (the inner loop of every sweep),
//!   the step-level collective simulator, and the fusion planner.
//! * L3 coordinator: ring collectives on real tensors and one full
//!   distributed mini-batch (when artifacts are built).
//! * Runtime: PJRT executable-cache hit path.

mod common;

use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, LinkConfig, PackageKind};
use hecaton::memory::dram::DramModel;
use hecaton::nop::analytic::Method;
use hecaton::nop::collective::{
    flat_ring_all_reduce, ring_step_collective, ring_step_schedule, CollectiveKind,
};
use hecaton::parallel::plan::planner;
use hecaton::runtime::Tensor;
use hecaton::sched::fusion::plan_fusion;
use hecaton::sched::pipeline::{
    overlap_chain_event, overlap_chain_event_in, GroupStage, EVENT_ITEM_CAP,
};
use hecaton::sim::engine::{EngineArena, EventEngine, Service};
use hecaton::sim::system::{simulate, simulate_engine, EngineKind};
use hecaton::util::{Bytes, Seconds};
use hecaton::workload::ops::BlockDesc;
use hecaton::workload::transformer::layer_blocks;

fn main() {
    let mut b = common::Bench::new("hotpath");

    // ── L3 simulator ──
    let model = model_preset("llama2-70b").unwrap();
    let hw = HardwareConfig::square(256, PackageKind::Standard, DramKind::Ddr5_6400);
    b.bench("sim/simulate_llama70b_256d", || {
        common::black_box(simulate(&model, &hw, Method::Hecaton));
    });
    let model405 = model_preset("llama3.1-405b").unwrap();
    let hw1024 = HardwareConfig::square(1024, PackageKind::Standard, DramKind::Ddr5_6400);
    b.bench("sim/simulate_llama405b_1024d", || {
        common::black_box(simulate(&model405, &hw1024, Method::FlatRing));
    });

    // ── fusion planner (O(n) guard) ──
    // 405B's full 126-layer / 252-block chain: the planner used to
    // re-price the whole prefix per extension (O(n²)); this bench guards
    // the incremental rewrite.
    let chain405: Vec<BlockDesc> = (0..model405.layers)
        .flat_map(|_| layer_blocks(&model405))
        .collect();
    let hec = planner(Method::Hecaton);
    b.bench("sched/plan_fusion_252blocks", || {
        common::black_box(plan_fusion(&chain405, hec.as_ref(), &hw1024));
    });

    // ── discrete-event engine hot paths ──
    b.bench("engine/simulate_event_llama70b_256d", || {
        common::black_box(simulate_engine(&model, &hw, Method::Hecaton, EngineKind::Event));
    });
    b.bench("engine/simulate_prefetch_llama70b_256d", || {
        common::black_box(simulate_engine(
            &model,
            &hw,
            Method::Hecaton,
            EngineKind::EventPrefetch,
        ));
    });
    let link = LinkConfig::for_package(PackageKind::Standard);
    let ring_sched = ring_step_schedule(CollectiveKind::AllGather, 64, Bytes::mib(64.0));
    b.bench("engine/event_ring_ag_n64", || {
        common::black_box(ring_sched.event_time(&link));
    });
    let dram = DramModel::new(&hw);
    let chain: Vec<GroupStage> = (0..8)
        .map(|_| GroupStage {
            on_package: Seconds::ms(20.0),
            dram_bytes: Bytes::gib(4.0),
            n_minibatches: 256,
        })
        .collect();
    b.bench("engine/overlap_chain_8x256", || {
        common::black_box(overlap_chain_event(&chain, &dram, true));
    });
    // Same chain through a reused arena — the sweep service path.
    let mut chain_arena = EngineArena::new();
    b.bench("engine/overlap_chain_8x256_arena", || {
        common::black_box(overlap_chain_event_in(
            &mut chain_arena,
            &chain,
            &dram,
            true,
            EVENT_ITEM_CAP,
        ));
    });
    fn raw_graph(eng: &mut EventEngine) {
        let pkg = eng.fifo("pkg");
        let fabric = eng.fair("fabric", 1e11);
        let mut prev = None;
        for i in 0..5_000u64 {
            let deps: Vec<_> = prev.into_iter().collect();
            let d = eng.task(fabric, Service::Transfer(Bytes(1e6 + i as f64)), &deps);
            let p = eng.task(pkg, Service::Busy(Seconds(1e-5)), &[d]);
            prev = Some(p);
        }
    }
    b.bench("engine/raw_task_graph_10k", || {
        let mut eng = EventEngine::new();
        raw_graph(&mut eng);
        common::black_box(eng.run().makespan);
    });
    // Arena variant: reset + rebuild + execute with zero steady-state
    // allocation (the time-wheel and slabs keep their capacity).
    let mut graph_arena = EngineArena::new();
    b.bench("engine/raw_task_graph_10k_arena", || {
        graph_arena.engine.reset();
        raw_graph(&mut graph_arena.engine);
        graph_arena.kernel.execute(&graph_arena.engine);
        common::black_box(graph_arena.kernel.makespan());
    });

    // ── NoP collective step simulator ──
    b.bench("nop/ring_ag_n32", || {
        common::black_box(ring_step_collective(
            CollectiveKind::AllGather,
            32,
            Bytes::mib(64.0),
            &link,
        ));
    });
    b.bench("nop/flat_ring_ar_n1024", || {
        common::black_box(flat_ring_all_reduce(1024, Bytes::gib(1.0), &link));
    });

    // ── host tensor ops (coordinator inner loop) ──
    let mut rng = hecaton::util::rng::Rng::new(1);
    let big = Tensor::glorot(768, 1152, &mut rng);
    b.bench("tensor/transpose_768x1152", || {
        common::black_box(big.transpose());
    });
    let mut acc = Tensor::zeros(&[768, 1152]);
    b.bench("tensor/add_assign_768x1152", || {
        acc.add_assign(&big);
    });

    // ── coordinator collectives on real tensors ──
    b.bench("coord/rs_ag_ring4_64x256", || {
        use hecaton::coordinator::collective::build_ring;
        let ends = build_ring(4);
        let handles: Vec<_> = ends
            .into_iter()
            .enumerate()
            .map(|(p, end)| {
                std::thread::spawn(move || {
                    let t = Tensor::new(vec![p as f32; 64 * 256], vec![64, 256]);
                    let rs = end.reduce_scatter(&t).unwrap();
                    end.all_gather(rs).unwrap()
                })
            })
            .collect();
        for h in handles {
            common::black_box(h.join().unwrap());
        }
    });

    // ── PJRT runtime (artifact cache hit) ──
    if hecaton::runtime::artifact_dir().join("manifest.txt").exists() {
        let rt = hecaton::runtime::Runtime::open_default().unwrap();
        let x = Tensor::glorot(64, 32, &mut rng);
        let w = Tensor::glorot(32, 96, &mut rng);
        let _ = rt.matmul(&x, &w).unwrap(); // compile once
        b.bench("runtime/matmul_64x32x96_cached", || {
            common::black_box(rt.matmul(&x, &w).unwrap());
        });

        // One full distributed mini-batch through the 2x2 mesh.
        use hecaton::coordinator::{coord_model, Coordinator, MeshCfg};
        let cfg = MeshCfg::new(coord_model("tiny").unwrap(), 2, 2, 64);
        let mut coord = Coordinator::new(cfg, 5).unwrap();
        let mut corpus = hecaton::train::data::Corpus::next_token(64, 32, 9);
        let (tokens, targets) = corpus.minibatch(64);
        b.bench("coord/grad_step_tiny_2x2", || {
            common::black_box(coord.grad_step(&tokens, &targets).unwrap());
        });
        coord.shutdown().unwrap();
    } else {
        eprintln!("(artifacts not built — skipping runtime/coordinator benches)");
    }

    b.finish_with_json("BENCH_engine.json");
}
