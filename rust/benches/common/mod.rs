//! Mini-criterion: a bench harness for `harness = false` bench targets
//! (criterion is not in the offline vendor set — see ARCHITECTURE.md).
//!
//! Usage inside a bench binary:
//! ```ignore
//! mod common;
//! fn main() {
//!     let mut b = common::Bench::new("fig8");
//!     b.bench("fig8/grid", || { hecaton::report::fig8::run(); });
//!     b.finish();
//! }
//! ```
//!
//! Prints per-bench mean/median/p95 and writes nothing to disk; the
//! experiment *content* (the paper tables) is printed once before timing.

use std::time::{Duration, Instant};

use hecaton::util::stats::Summary;

/// Target minimum measurement time per bench.
const TARGET_TIME: Duration = Duration::from_secs(2);
/// Hard cap on iterations.
const MAX_ITERS: usize = 200;

pub struct Bench {
    suite: &'static str,
    results: Vec<(String, Summary)>,
}

impl Bench {
    pub fn new(suite: &'static str) -> Bench {
        eprintln!("== bench suite: {suite} ==");
        Bench {
            suite,
            results: Vec::new(),
        }
    }

    /// Time `f` adaptively: warm up once, then iterate until the target
    /// time or the iteration cap is reached.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        // Warmup.
        f();
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < TARGET_TIME && samples.len() < MAX_ITERS {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::from(&samples).expect("at least one sample");
        println!(
            "bench {:40} {:>6} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            name,
            s.n,
            hecaton::util::fmt::seconds(s.mean),
            hecaton::util::fmt::seconds(s.median),
            hecaton::util::fmt::seconds(s.p95),
        );
        self.results.push((name.to_string(), s));
    }

    /// Print the suite footer.
    pub fn finish(self) {
        eprintln!(
            "== {}: {} benches complete ==",
            self.suite,
            self.results.len()
        );
    }

    /// Write the suite's results as machine-readable JSON (one object per
    /// bench) before printing the footer, so perf trajectories can be
    /// tracked across commits (e.g. `BENCH_engine.json`).
    #[allow(dead_code)] // each bench binary includes this module; not all emit JSON
    pub fn finish_with_json(self, path: &str) {
        let mut s = String::from("[\n");
        for (i, (name, r)) in self.results.iter().enumerate() {
            if i > 0 {
                s.push_str(",\n");
            }
            s.push_str(&format!(
                "  {{\"suite\": \"{}\", \"name\": \"{}\", \"iters\": {}, \
                 \"mean_s\": {:e}, \"median_s\": {:e}, \"p95_s\": {:e}, \
                 \"min_s\": {:e}, \"max_s\": {:e}}}",
                json_escape(self.suite),
                json_escape(name),
                r.n,
                r.mean,
                r.median,
                r.p95,
                r.min,
                r.max,
            ));
        }
        s.push_str("\n]\n");
        match std::fs::write(path, &s) {
            Ok(()) => eprintln!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
        self.finish();
    }
}

/// Minimal JSON string escaping (bench names are plain identifiers, but
/// don't let a stray quote corrupt the file).
#[allow(dead_code)]
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
