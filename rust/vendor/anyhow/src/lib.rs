//! Minimal, dependency-free subset of the `anyhow` API, vendored so the
//! crate builds with no network access (see `rust/Cargo.toml`).
//!
//! Implemented surface — exactly what this repository uses:
//!
//! * [`Error`]: an owned error with a context chain; `{}` prints the
//!   outermost message, `{:#}` prints the whole chain joined by `: `
//!   (matching anyhow's alternate formatting, which the CLI and tests rely
//!   on).
//! * [`Result<T>`] alias.
//! * [`anyhow!`] / [`bail!`] macros with format-string support.
//! * [`Context`] for adding context to `Result` and `Option`.
//! * A blanket `From<E: std::error::Error>` so `?` converts any standard
//!   error (the source chain is flattened into the context chain).
//!
//! Dropped relative to the real crate: downcasting, backtraces and
//! `ensure!` — none are used here. Swap this path dependency for the real
//! `anyhow` in `rust/Cargo.toml` if those are ever needed.

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (first, rest) = match self.chain.split_first() {
            Some(x) => x,
            None => return write!(f, "(empty error)"),
        };
        write!(f, "{first}")?;
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::anyhow!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return Err($crate::anyhow!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
}

/// Add context to errors (and to `None`).
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "no such file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening manifest").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening manifest: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn macros_format() {
        let name = "fig8";
        let e = anyhow!("unknown experiment '{name}'");
        assert_eq!(format!("{e}"), "unknown experiment 'fig8'");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(format!("{e}"), "1 + 2");
        fn f() -> Result<()> {
            bail!("boom {}", 42);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 42");
    }
}
