//! Stub of the `xla` PJRT bindings used by `hecaton::runtime`.
//!
//! The functional training path executes AOT-compiled HLO artifacts via
//! PJRT. The real bindings link a multi-gigabyte native XLA build that is
//! not available in offline/CI environments, so this stub provides the
//! exact API surface `hecaton::runtime::client` compiles against and
//! returns a clear error the moment artifact execution is attempted.
//!
//! Everything else in the crate — the whole chiplet system simulator, the
//! discrete-event engine, every paper report — is pure Rust and fully
//! functional with this stub.
//!
//! To run the functional path, point the `xla` entry of `rust/Cargo.toml`
//! at the real bindings (e.g. a checkout of `elixir-nx/xla` bindings or a
//! crates.io `xla` release exposing `PjRtClient`, `PjRtLoadedExecutable`,
//! `HloModuleProto`, `XlaComputation`, `Literal`) and rebuild; no source
//! changes are needed.

use std::fmt;

/// Error type: everything fails with an "unavailable" message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable — this build vendors the `xla` stub crate \
         (rust/vendor/xla); swap in the real xla bindings to execute \
         compiled artifacts"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        // Literal construction/reshape succeed so input validation paths
        // upstream of execution still run.
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
