"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT export.

Never imported at runtime — the rust binary only reads artifacts/.
"""
