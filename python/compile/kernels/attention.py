"""Single-head attention Pallas kernel.

The paper keeps each attention head resident on one die (Algorithm 1,
Steps 10–12: reduce-scatter puts Q, K, V of a head on the same die and the
head computes locally with zero inter-die traffic). The kernel mirrors
that: one grid step = one head, computing ``softmax(QKᵀ/√d)·V`` entirely
in VMEM with a numerically-stable softmax.

Backward is derived with ``jax.vjp`` over the same kernel (interpret-mode
Pallas is differentiable), so the AOT'd backward artifact exercises the
identical code path the forward uses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[0]  # block (1, s, d) -> [s, d]
    k = k_ref[0]
    v = v_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Stable softmax.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def attention_fwd(q, k, v):
    """``softmax(QKᵀ/√d)·V`` for a batch of heads: inputs ``[h, s, d]``."""
    heads, s, d = q.shape
    scale = 1.0 / (d**0.5)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, scale):
    """Analytic attention backward, one head per grid step.

    With p = softmax(qkᵀ·scale):
      dv = pᵀ·do
      dp = do·vᵀ
      ds = p ⊙ (dp − rowsum(dp ⊙ p))   (softmax vjp)
      dq = ds·k·scale ;  dk = dsᵀ·q·scale
    """
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    dv_ref[0] = jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0] = jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale
    dk_ref[0] = jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale


@jax.jit
def attention_bwd(q, k, v, do):
    """Gradients (dq, dk, dv) of `attention_fwd` under cotangent `do`."""
    heads, s, d = q.shape
    scale = 1.0 / (d**0.5)
    spec = pl.BlockSpec((1, s, d), lambda h: (h, 0, 0))
    shape = jax.ShapeDtypeStruct((heads, s, d), jnp.float32)
    return pl.pallas_call(
        functools.partial(_attn_bwd_kernel, scale=scale),
        grid=(heads,),
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[shape] * 3,
        interpret=True,
    )(q, k, v, do)
