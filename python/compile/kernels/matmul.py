"""Tiled matmul Pallas kernel — the per-die PE-array analogue.

Grid = (M/bm, N/bn, K/bk) with K innermost; the output tile (whose block
index is constant along K) acts as the accumulator: zeroed on the first K
step, accumulated on every step — mirroring the weight-stationary
accumulation of the paper's MAC array (and the classic MXU matmul
schedule). Block sizes adapt to the problem so small coordinator tiles
(e.g. 32×64×96) work as well as wide FFN tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Default (bm, bk, bn) tile; shrunk per-dimension when the problem is
# smaller. Chosen in the EXPERIMENTS.md §Perf L1 iteration: the live VMEM
# tiles cost 5.6 MiB (double-buffers inside a 16 MiB VMEM) while keeping
# the HBM<->VMEM grid small — the (64,128,128) starting point spent most
# of the e2e-100m execution on grid-step overhead (9.7x end-to-end after
# this change); the next size up (1024,2048,1152) gained 13% more on the
# CPU but exceeds the VMEM budget, so it was rejected as structurally
# invalid for the real-TPU target.
DEFAULT_BLOCK = (512, 1024, 576)


def _largest_divisor_block(dim, cap):
    """Largest divisor of `dim` that is <= cap (keeps grids exact)."""
    b = min(cap, dim)
    while dim % b != 0:
        b -= 1
    return b


def block_dims(m, k, n, block=DEFAULT_BLOCK):
    bm, bk, bn = block
    return (
        _largest_divisor_block(m, bm),
        _largest_divisor_block(k, bk),
        _largest_divisor_block(n, bn),
    )


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps):
    """One (i, j, kk) grid step: o_tile (+)= x_tile @ w_tile."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x, w, block=DEFAULT_BLOCK):
    """``x[m,k] @ w[k,n]`` via the Pallas kernel (interpret mode)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bk, bn = block_dims(m, k, n, block)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_footprint_bytes(m, k, n, block=DEFAULT_BLOCK):
    """Estimated VMEM bytes live per grid step (x, w, o tiles).

    Used by the perf report: interpret-mode wallclock is not a TPU proxy,
    so we optimize/validate the *structure* — footprint must fit VMEM
    (≈16 MiB/core) with room for double buffering.
    """
    bm, bk, bn = block_dims(m, k, n, block)
    return 4 * (bm * bk + bk * bn + bm * bn)
