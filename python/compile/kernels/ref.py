"""Pure-jnp oracles for every kernel (the build-time correctness bar).

pytest (with hypothesis sweeps) asserts kernel == oracle before any
artifact is emitted; `aot.py` refuses to export if the smoke equivalence
fails.
"""

import jax
import jax.numpy as jnp

EPS = 1e-5


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def attention_ref(q, k, v):
    """[h, s, d] single-head-per-grid attention reference."""
    d = q.shape[-1]
    scores = jnp.einsum("hsd,htd->hst", q, k) / (d**0.5)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hst,htd->hsd", p, v)


def rmsnorm_ref(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * g


def gelu_ref(x):
    return jax.nn.gelu(x, approximate=True)


def softmax_xent_ref(logits, targets):
    n = logits.shape[0]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1).squeeze(-1)
    loss = jnp.mean(nll)
    p = jnp.exp(logp)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    return loss, (p - onehot) / n
