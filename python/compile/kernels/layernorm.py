"""RMSNorm Pallas kernel (row-wise normalization on the vector unit).

The paper's normalization runs on the die's vector unit; the kernel tiles
rows so each grid step normalizes a block of tokens over the full hidden
dimension (normalization needs the whole row — this is why the functional
coordinator applies norms at block boundaries where full-width activations
exist; see `rust/src/coordinator`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _rmsnorm_kernel(x_ref, g_ref, o_ref):
    x = x_ref[...]  # [bm, h]
    g = g_ref[...]  # [h]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + EPS) * g


def _row_block(n):
    b = min(64, n)
    while n % b != 0:
        b -= 1
    return b


@jax.jit
def rmsnorm_fwd(x, g):
    """RMSNorm over the last dim: ``x·rsqrt(mean(x²)+ε)·g``; x is [n, h]."""
    n, h = x.shape
    bm = _row_block(n)
    return pl.pallas_call(
        _rmsnorm_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(x, g)


def _rmsnorm_ref(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + EPS) * g


@jax.jit
def rmsnorm_bwd(x, g, dy):
    """Gradients (dx, dg) of RMSNorm under cotangent `dy`.

    Derived from the jnp formulation (Pallas interpret calls don't admit
    reverse-mode AD); pytest asserts `rmsnorm_fwd == _rmsnorm_ref` so the
    gradients are exact for the kernel too. Vector-unit work either way.
    """
    _, vjp = jax.vjp(_rmsnorm_ref, x, g)
    return vjp(dy)


# Plain-jnp element-wise pieces, AOT'd alongside the kernels (the vector
# unit handles these; no tiling subtlety so no Pallas needed).


@jax.jit
def gelu_fwd(x):
    return jax.nn.gelu(x, approximate=True)


@jax.jit
def gelu_bwd(x, dy):
    _, vjp = jax.vjp(gelu_fwd, x)
    return vjp(dy)[0]


@functools.partial(jax.jit, static_argnames=())
def softmax_xent(logits, targets):
    """Mean cross-entropy + dLogits for integer targets.

    Returns ``(loss, dlogits)`` — the only loss-side artifact the
    coordinator needs (it backpropagates from dlogits).
    """
    n = logits.shape[0]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1).squeeze(-1)
    loss = jnp.mean(nll)
    p = jnp.exp(logp)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    dlogits = (p - onehot) / n
    return loss, dlogits
