"""AOT pipeline: lower every kernel/entry-point to HLO **text** artifacts.

HLO text (not serialized protos) is the interchange format — the image's
xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos, and the text
parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Python runs exactly once, at build time; the rust coordinator loads these
files through PJRT and never calls back into python.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.attention import attention_bwd, attention_fwd
from .kernels.layernorm import (
    gelu_bwd,
    gelu_fwd,
    rmsnorm_bwd,
    rmsnorm_fwd,
    softmax_xent,
)
from .kernels.matmul import matmul
from .kernels import ref
from .model import CONFIGS, aux_shapes, hecaton_tile_shapes

# (model, mesh_rows, mesh_cols, minibatch_tokens) triples whose artifacts
# the rust examples/tests request. Keep in sync with
# `rust/src/coordinator/mesh.rs::artifact_plan` (pinned by pytest).
DEPLOYMENTS = [
    ("tiny", 1, 1, 64),
    ("tiny", 2, 2, 64),
    ("e2e-100m", 2, 2, 256),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_points():
    """name -> (fn, example_args). Deduplicated across deployments."""
    entries = {}

    def add(name, fn, *args):
        if name not in entries:
            entries[name] = (fn, args)

    for model_name, rows, cols, tokens in DEPLOYMENTS:
        cfg = CONFIGS[model_name]
        for (m, k, n) in hecaton_tile_shapes(cfg, rows, cols, tokens):
            add(f"matmul_{m}x{k}x{n}", lambda x, w: (matmul(x, w),), f32(m, k), f32(k, n))
        aux = aux_shapes(cfg, rows, cols, tokens)
        h, s, d = aux["attention"]
        add(
            f"attention_fwd_{h}x{s}x{d}",
            lambda q, k, v: (attention_fwd(q, k, v),),
            f32(h, s, d), f32(h, s, d), f32(h, s, d),
        )
        add(
            f"attention_bwd_{h}x{s}x{d}",
            lambda q, k, v, do: tuple(attention_bwd(q, k, v, do)),
            f32(h, s, d), f32(h, s, d), f32(h, s, d), f32(h, s, d),
        )
        nt, hh = aux["rmsnorm"]
        add(
            f"rmsnorm_fwd_{nt}x{hh}",
            lambda x, g: (rmsnorm_fwd(x, g),),
            f32(nt, hh), f32(hh),
        )
        add(
            f"rmsnorm_bwd_{nt}x{hh}",
            lambda x, g, dy: tuple(rmsnorm_bwd(x, g, dy)),
            f32(nt, hh), f32(hh), f32(nt, hh),
        )
        gm, gn = aux["gelu"]
        add(f"gelu_fwd_{gm}x{gn}", lambda x: (gelu_fwd(x),), f32(gm, gn))
        add(
            f"gelu_bwd_{gm}x{gn}",
            lambda x, dy: (gelu_bwd(x, dy),),
            f32(gm, gn), f32(gm, gn),
        )
        xn, xv = aux["xent"]
        add(
            f"xent_{xn}x{xv}",
            lambda l, t: softmax_xent(l, t),
            f32(xn, xv), i32(xn),
        )
    return entries


def smoke_check():
    """Cheap kernel-vs-oracle equivalence before exporting anything."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (32, 96), jnp.float32)
    w = jax.random.normal(ks[1], (96, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)), np.asarray(ref.matmul_ref(x, w)), rtol=2e-5, atol=2e-5
    )
    q = jax.random.normal(ks[2], (4, 16, 8), jnp.float32)
    kk = jax.random.normal(ks[3], (4, 16, 8), jnp.float32)
    v = jax.random.normal(ks[4], (4, 16, 8), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(attention_fwd(q, kk, v)),
        np.asarray(ref.attention_ref(q, kk, v)),
        rtol=2e-5,
        atol=2e-5,
    )
    g = jnp.ones((96,), jnp.float32)
    xx = jax.random.normal(ks[5], (16, 96), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rmsnorm_fwd(xx, g)), np.asarray(ref.rmsnorm_ref(xx, g)), rtol=2e-5, atol=2e-5
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="emit a single entry point")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    smoke_check()
    entries = entry_points()
    manifest_lines = []
    for name, (fn, example_args) in sorted(entries.items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = ";".join(
            f"{'x'.join(map(str, a.shape))}:{a.dtype}" for a in example_args
        )
        manifest_lines.append(f"{name} {ins}")
        print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"emitted {len(manifest_lines)} artifacts to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
