"""Layer-2: the transformer model in JAX, built on the L1 kernels.

Two uses:
1. **Reference** — `model_loss` / `train_step` give a single-device oracle
   for the distributed coordinator's numerics (pytest compares the rust
   1×1-mesh run against this trajectory).
2. **Shape source** — `hecaton_tile_shapes` mirrors the rust planner's
   Algorithm-1 tiling so `aot.py` knows exactly which matmul artifacts the
   coordinator will request. `python/tests/test_model.py` pins the
   enumeration against hand-computed lists to prevent drift.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import attention_fwd
from .kernels.layernorm import gelu_fwd, rmsnorm_fwd, softmax_xent
from .kernels.matmul import matmul


@dataclass(frozen=True)
class ModelCfg:
    """Mirror of the rust `tiny`/`e2e-100m` presets (non-gated FFN)."""

    name: str
    hidden: int
    intermediate: int
    layers: int
    heads: int
    seq_len: int
    batch: int
    vocab: int

    @property
    def head_dim(self):
        return self.hidden // self.heads

    @property
    def qkv_out(self):
        return 3 * self.hidden


TINY = ModelCfg("tiny", hidden=64, intermediate=256, layers=2, heads=4,
                seq_len=32, batch=8, vocab=64)
E2E_100M = ModelCfg("e2e-100m", hidden=768, intermediate=3072, layers=12,
                    heads=12, seq_len=256, batch=8, vocab=512)

CONFIGS = {c.name: c for c in (TINY, E2E_100M)}


def init_params(cfg: ModelCfg, key):
    """Xavier-ish init; flat dict keyed like the rust coordinator's store."""
    params = {}
    k = iter(jax.random.split(key, 4 + 6 * cfg.layers))

    def glorot(key, shape):
        fan = sum(shape)
        return jax.random.normal(key, shape, jnp.float32) * (2.0 / fan) ** 0.5

    params["embed"] = glorot(next(k), (cfg.vocab, cfg.hidden))
    for i in range(cfg.layers):
        params[f"l{i}.w_qkv"] = glorot(next(k), (cfg.hidden, cfg.qkv_out))
        params[f"l{i}.w_o"] = glorot(next(k), (cfg.hidden, cfg.hidden))
        params[f"l{i}.w_up"] = glorot(next(k), (cfg.hidden, cfg.intermediate))
        params[f"l{i}.w_down"] = glorot(next(k), (cfg.intermediate, cfg.hidden))
        params[f"l{i}.norm1"] = jnp.ones((cfg.hidden,), jnp.float32)
        params[f"l{i}.norm2"] = jnp.ones((cfg.hidden,), jnp.float32)
    params["norm_f"] = jnp.ones((cfg.hidden,), jnp.float32)
    params["lm_head"] = glorot(next(k), (cfg.hidden, cfg.vocab))
    return params


def forward(params, tokens, cfg: ModelCfg, use_kernels=True):
    """Logits for `tokens` of shape [n] (already flattened batch·seq).

    `use_kernels=True` routes matmul/attention/norm through the Pallas
    kernels (the artifact path); `False` uses the differentiable jnp
    oracles — needed for `jax.grad` since interpret-mode `pallas_call`
    does not admit reverse-mode AD. `test_model.py` pins the two paths
    equal, so gradients of the oracle path are gradients of the kernels.
    """
    from .kernels import ref as _ref

    mm = matmul if use_kernels else _ref.matmul_ref
    attn = attention_fwd if use_kernels else _ref.attention_ref
    norm = rmsnorm_fwd if use_kernels else _ref.rmsnorm_ref

    n = tokens.shape[0]
    seqs = n // cfg.seq_len
    x = params["embed"][tokens]  # [n, h]
    for i in range(cfg.layers):
        # Attention block (pre-norm).
        xn = norm(x, params[f"l{i}.norm1"])
        qkv = mm(xn, params[f"l{i}.w_qkv"])  # [n, 3h]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return (
                t.reshape(seqs, cfg.seq_len, cfg.heads, cfg.head_dim)
                .transpose(0, 2, 1, 3)
                .reshape(seqs * cfg.heads, cfg.seq_len, cfg.head_dim)
            )

        a = attn(heads(q), heads(k), heads(v))
        a = (
            a.reshape(seqs, cfg.heads, cfg.seq_len, cfg.head_dim)
            .transpose(0, 2, 1, 3)
            .reshape(n, cfg.hidden)
        )
        x = x + mm(a, params[f"l{i}.w_o"])
        # FFN block.
        xn = norm(x, params[f"l{i}.norm2"])
        z = gelu_fwd(mm(xn, params[f"l{i}.w_up"]))
        x = x + mm(z, params[f"l{i}.w_down"])
    xn = norm(x, params["norm_f"])
    return mm(xn, params["lm_head"])


def model_loss(params, tokens, targets, cfg: ModelCfg, use_kernels=True):
    logits = forward(params, tokens, cfg, use_kernels=use_kernels)
    loss, _ = softmax_xent(logits, targets)
    return loss


def train_step(params, tokens, targets, lr, cfg: ModelCfg):
    """One SGD step; returns (loss, new_params)."""
    loss, grads = jax.value_and_grad(
        lambda p: model_loss(p, tokens, targets, cfg, use_kernels=False)
    )(params)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return loss, new_params


# ───────────────── Algorithm-1 tile-shape enumeration ─────────────────


def linears_of(cfg: ModelCfg):
    """(name, in_dim, out_dim, orientation_idx) per block linear.

    orientation_idx 0 = first (gather within columns, ring length R),
    1 = last (transposed). Mirrors `rust/src/parallel/hecaton.rs`.
    """
    return [
        ("w_qkv", cfg.hidden, cfg.qkv_out, 0),
        ("w_o", cfg.hidden, cfg.hidden, 1),
        ("w_up", cfg.hidden, cfg.intermediate, 0),
        ("w_down", cfg.intermediate, cfg.hidden, 1),
    ]


def ceil_div(a, b):
    return -(-a // b)


def hecaton_tile_shapes(cfg: ModelCfg, rows, cols, tokens):
    """All per-die matmul shapes (m, k, n) the coordinator requests for one
    (model, mesh, mini-batch) triple: fwd, dX, dW per linear, plus the
    LM-head shapes executed on the leader."""
    shapes = set()
    for _, in_dim, out_dim, orient in linears_of(cfg):
        gather, scatter = (rows, cols) if orient == 0 else (cols, rows)
        k = ceil_div(in_dim, scatter)
        n = ceil_div(out_dim, gather)
        shapes.add((tokens, k, n))  # fwd
        shapes.add((tokens, n, k))  # dX = dY · Wᵀ
        shapes.add((k, tokens, n))  # dW = Xᵀ · dY
    # LM head on the leader (full width).
    shapes.add((tokens, cfg.hidden, cfg.vocab))
    shapes.add((tokens, cfg.vocab, cfg.hidden))
    shapes.add((cfg.hidden, tokens, cfg.vocab))
    return sorted(shapes)


def aux_shapes(cfg: ModelCfg, rows, cols, tokens):
    """Non-matmul artifact shapes for a (model, mesh, mini-batch) triple."""
    seqs = max(1, tokens // cfg.seq_len)
    n_dies = rows * cols
    assert (seqs * cfg.heads) % n_dies == 0, "head batches must divide dies"
    return {
        # Heads are distributed across the N dies (paper Steps 10-12);
        # the artifact shape is one die's chunk.
        "attention": (seqs * cfg.heads // n_dies, cfg.seq_len, cfg.head_dim),
        "rmsnorm": (tokens, cfg.hidden),
        # gelu runs die-local on the up-projection's output tile
        # [tokens/scatter, intermediate/gather] (orientation 0: gather=R,
        # scatter=C) — no communication, exactly as the fused flow keeps
        # the intermediate on-package.
        "gelu": (ceil_div(tokens, cols), ceil_div(cfg.intermediate, rows)),
        "xent": (tokens, cfg.vocab),
    }
