"""Attention kernels (fwd + analytic bwd) vs jnp oracle + autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention_bwd, attention_fwd


def qkv(seed, h, s, d):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    return [jax.random.normal(k, (h, s, d), jnp.float32) for k in ks]


@pytest.mark.parametrize("h,s,d", [(1, 4, 4), (4, 16, 8), (8, 32, 16), (12, 256, 64)])
def test_fwd_matches_oracle(h, s, d):
    q, k, v, _ = qkv(h * s + d, h, s, d)
    got = np.asarray(attention_fwd(q, k, v))
    want = np.asarray(ref.attention_ref(q, k, v))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("h,s,d", [(1, 4, 4), (4, 16, 8), (8, 32, 16)])
def test_bwd_matches_autodiff_of_oracle(h, s, d):
    q, k, v, do = qkv(17 + h, h, s, d)
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    want = vjp(do)
    got = attention_bwd(q, k, v, do)
    for g, w, name in zip(got, want, "qkv"):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=5e-5, atol=5e-5,
            err_msg=f"d{name}",
        )


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 6),
    s=st.sampled_from([2, 4, 8, 16, 32]),
    d=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_fwd_bwd_consistency(h, s, d, seed):
    q, k, v, do = qkv(seed, h, s, d)
    got_o = np.asarray(attention_fwd(q, k, v))
    want_o = np.asarray(ref.attention_ref(q, k, v))
    np.testing.assert_allclose(got_o, want_o, rtol=5e-5, atol=5e-5)
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    want = vjp(do)
    got = attention_bwd(q, k, v, do)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4)


def test_softmax_rows_sum_to_one_property():
    # With v = identity-ish rows the output of a uniform-score attention is
    # the mean of v rows — a quick semantic check.
    h, s, d = 2, 8, 8
    q = jnp.zeros((h, s, d), jnp.float32)
    k = jnp.zeros((h, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(0), (h, s, d), jnp.float32)
    out = np.asarray(attention_fwd(q, k, v))
    want = np.broadcast_to(np.asarray(v).mean(axis=1, keepdims=True), out.shape)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
