"""AOT pipeline: every entry point lowers to parseable HLO text with the
expected parameter arity; the manifest stays in sync."""

import os

import pytest

from compile.aot import DEPLOYMENTS, entry_points, to_hlo_text
import jax


@pytest.fixture(scope="module")
def entries():
    return entry_points()


def test_deployments_cover_examples(entries):
    # The rust examples need tiny@1x1, tiny@2x2 and e2e-100m@2x2.
    assert ("tiny", 1, 1, 64) in DEPLOYMENTS
    assert ("tiny", 2, 2, 64) in DEPLOYMENTS
    assert ("e2e-100m", 2, 2, 256) in DEPLOYMENTS


def test_entry_point_names_unique_and_shaped(entries):
    assert len(entries) > 30
    for name in entries:
        kind = name.split("_")[0]
        assert kind in {"matmul", "attention", "rmsnorm", "gelu", "xent"}, name


@pytest.mark.parametrize("name", ["matmul_64x32x96", "attention_fwd_8x32x16",
                                  "rmsnorm_fwd_64x64", "xent_64x64"])
def test_lowering_produces_hlo_text(entries, name):
    fn, args = entries[name]
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule"), text[:80]
    # return_tuple=True: the root is a tuple.
    assert "ROOT" in text
    # Count parameters of the ENTRY computation only (fusion bodies also
    # contain `parameter(` lines). The ENTRY block runs from its header
    # line to the first unindented closing brace.
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    block = []
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        block.append(l)
    n_params = sum("parameter(" in l for l in block)
    assert n_params == len(args), f"{name}: {n_params} params vs {len(args)} args"


def test_emitted_artifacts_match_entry_points(entries):
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("artifacts/ not built yet (run `make artifacts`)")
    on_disk = {f[: -len(".hlo.txt")] for f in os.listdir(art) if f.endswith(".hlo.txt")}
    assert on_disk == set(entries), sorted(on_disk ^ set(entries))
    manifest = os.path.join(art, "manifest.txt")
    with open(manifest) as f:
        names = {line.split()[0] for line in f if line.strip()}
    assert names == set(entries)
