"""L2 model: shapes, loss behaviour, gradient sanity, and the tile-shape
enumeration the rust coordinator depends on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    CONFIGS,
    TINY,
    aux_shapes,
    forward,
    hecaton_tile_shapes,
    init_params,
    model_loss,
    train_step,
)


def data(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    n = cfg.batch * cfg.seq_len
    tokens = jax.random.randint(key, (n,), 0, cfg.vocab)
    # Synthetic next-token task: target = (token + 1) mod vocab.
    targets = (tokens + 1) % cfg.vocab
    return tokens, targets


def test_forward_shapes():
    params = init_params(TINY, jax.random.PRNGKey(0))
    tokens, _ = data(TINY)
    logits = forward(params, tokens, TINY)
    assert logits.shape == (TINY.batch * TINY.seq_len, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_kernel_and_oracle_paths_agree():
    """Pins the gradient argument: the jnp-oracle forward (through which
    `train_step` differentiates) equals the Pallas-kernel forward."""
    params = init_params(TINY, jax.random.PRNGKey(7))
    tokens, _ = data(TINY, seed=8)
    lk = forward(params, tokens, TINY, use_kernels=True)
    lo = forward(params, tokens, TINY, use_kernels=False)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lo), rtol=2e-4, atol=2e-4)


def test_initial_loss_near_uniform():
    params = init_params(TINY, jax.random.PRNGKey(0))
    tokens, targets = data(TINY)
    loss = float(model_loss(params, tokens, targets, TINY))
    uniform = float(np.log(TINY.vocab))
    assert abs(loss - uniform) < 0.5, f"init loss {loss} vs ln V {uniform}"


def test_sgd_reduces_loss():
    params = init_params(TINY, jax.random.PRNGKey(1))
    tokens, targets = data(TINY, seed=2)
    losses = []
    for _ in range(12):
        loss, params = train_step(params, tokens, targets, 0.5, TINY)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, f"no learning: {losses}"


def test_gradients_flow_to_all_params():
    params = init_params(TINY, jax.random.PRNGKey(3))
    tokens, targets = data(TINY, seed=4)
    grads = jax.grad(lambda p: model_loss(p, tokens, targets, TINY, use_kernels=False))(params)
    for name, g in grads.items():
        assert float(jnp.max(jnp.abs(g))) > 0.0, f"dead gradient for {name}"


def test_tile_shape_enumeration_pinned_tiny_2x2():
    """Hand-computed Algorithm-1 tiles for tiny @ 2×2, w=64 — guards the
    python↔rust shape contract."""
    shapes = hecaton_tile_shapes(TINY, 2, 2, 64)
    expected = {
        # w_qkv (64→192, orient 0): fwd / dX / dW
        (64, 32, 96), (64, 96, 32), (32, 64, 96),
        # w_o (64→64, orient 1)
        (64, 32, 32), (32, 64, 32),
        # w_up (64→256, orient 0)
        (64, 32, 128), (64, 128, 32), (32, 64, 128),
        # w_down (256→64, orient 1): k=256/2=128, n=64/2=32
        (64, 128, 32), (128, 64, 32),
        # lm head on the leader
        (64, 64, 64), (64, 64, 64), (64, 64, 64),
    }
    assert set(shapes) == expected, sorted(set(shapes) ^ expected)


def test_aux_shapes_pinned_tiny_2x2():
    aux = aux_shapes(TINY, 2, 2, 64)
    assert aux["attention"] == (2, 32, 16)  # (2 seqs × 4 heads) / 4 dies
    assert aux["rmsnorm"] == (64, 64)
    assert aux["gelu"] == (32, 128)
    assert aux["xent"] == (64, 64)


def test_shapes_for_reference_mesh_1x1():
    shapes = hecaton_tile_shapes(TINY, 1, 1, 64)
    # On 1×1 every linear is dense.
    assert (64, 64, 192) in shapes  # qkv fwd
    assert (64, 256, 64) in shapes  # down fwd
    aux = aux_shapes(TINY, 1, 1, 64)
    assert aux["gelu"] == (64, 256)


def test_e2e_config_is_about_100m():
    cfg = CONFIGS["e2e-100m"]
    stack = cfg.layers * (4 * cfg.hidden**2 + 2 * cfg.hidden * cfg.intermediate)
    embeds = 2 * cfg.vocab * cfg.hidden
    total = stack + embeds
    assert 6e7 < total < 1.6e8, total
