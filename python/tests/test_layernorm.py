"""RMSNorm / GeLU / cross-entropy kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.layernorm import (
    gelu_bwd,
    gelu_fwd,
    rmsnorm_bwd,
    rmsnorm_fwd,
    softmax_xent,
)


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("n,h", [(1, 4), (16, 96), (64, 64), (256, 768)])
def test_rmsnorm_fwd(n, h):
    x, g = rand(n, n, h), 1.0 + 0.1 * rand(h, h)
    np.testing.assert_allclose(
        np.asarray(rmsnorm_fwd(x, g)), np.asarray(ref.rmsnorm_ref(x, g)),
        rtol=2e-5, atol=2e-5,
    )


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 64), h=st.integers(2, 128), seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_bwd_matches_autodiff(n, h, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, h), jnp.float32)
    g = 1.0 + 0.1 * jax.random.normal(k2, (h,), jnp.float32)
    dy = jax.random.normal(k3, (n, h), jnp.float32)
    _, vjp = jax.vjp(ref.rmsnorm_ref, x, g)
    want_dx, want_dg = vjp(dy)
    got_dx, got_dg = rmsnorm_bwd(x, g, dy)
    np.testing.assert_allclose(np.asarray(got_dx), np.asarray(want_dx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_dg), np.asarray(want_dg), rtol=1e-4, atol=1e-4)


def test_gelu_roundtrip():
    x = rand(3, 32, 128)
    np.testing.assert_allclose(
        np.asarray(gelu_fwd(x)), np.asarray(ref.gelu_ref(x)), rtol=1e-6, atol=1e-6
    )
    dy = rand(4, 32, 128)
    _, vjp = jax.vjp(ref.gelu_ref, x)
    np.testing.assert_allclose(
        np.asarray(gelu_bwd(x, dy)), np.asarray(vjp(dy)[0]), rtol=1e-5, atol=1e-5
    )


def test_xent_loss_and_grad():
    logits = rand(5, 64, 32)
    targets = jax.random.randint(jax.random.PRNGKey(9), (64,), 0, 32)
    loss, dlogits = softmax_xent(logits, targets)
    want_loss, want_d = ref.softmax_xent_ref(logits, targets)
    np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dlogits), np.asarray(want_d), rtol=1e-5, atol=1e-6)
    # Grad of mean-NLL sums to ~0 per row for the true softmax Jacobian.
    np.testing.assert_allclose(np.asarray(dlogits).sum(axis=-1), 0.0, atol=1e-6)


def test_xent_perfect_prediction_low_loss():
    n, v = 16, 8
    targets = jnp.arange(n, dtype=jnp.int32) % v
    logits = 20.0 * jax.nn.one_hot(targets, v, dtype=jnp.float32)
    loss, _ = softmax_xent(logits, targets)
    assert float(loss) < 1e-3
