"""L1 matmul kernel vs pure-jnp oracle, with hypothesis shape sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import (
    DEFAULT_BLOCK,
    block_dims,
    matmul,
    vmem_footprint_bytes,
)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (64, 96, 160),
        (32, 64, 96),  # a coordinator tile for the tiny model
        (64, 32, 96),
        (128, 256, 64),
        (7, 13, 5),  # primes: forces 1-sized blocks on some dims
        (256, 384, 1152),  # an e2e-100m tile
    ],
)
def test_matches_oracle(m, k, n):
    x, w = rand(m, *(m, k)), rand(n, *(k, n))
    got = np.asarray(matmul(x, w))
    want = np.asarray(ref.matmul_ref(x, w))
    # Tolerance scales mildly with K: tiled accumulation reassociates sums.
    tol = 2e-5 * max(1.0, (k / 64.0) ** 0.5)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shape_sweep(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    got = np.asarray(matmul(x, w))
    want = np.asarray(x @ w)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 2048), k=st.integers(1, 2048), n=st.integers(1, 2048))
def test_block_dims_divide(m, k, n):
    bm, bk, bn = block_dims(m, k, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    assert bm <= DEFAULT_BLOCK[0] and bk <= DEFAULT_BLOCK[1] and bn <= DEFAULT_BLOCK[2]


def test_vmem_footprint_reasonable():
    # Default blocks at (4096)³: bm=512, bk=1024, bn=512 (largest divisor
    # ≤576) → 5.24 MiB live, which double-buffers inside a 16 MiB VMEM.
    fp = vmem_footprint_bytes(4096, 4096, 4096)
    assert fp == 4 * (512 * 1024 + 1024 * 512 + 512 * 512)
    assert fp * 2 < 16 * 2**20


def test_accumulation_order_stability():
    # Long-K accumulation must not blow up numerically.
    x, w = rand(1, 8, 2048), rand(2, 2048, 8)
    got = np.asarray(matmul(x, w))
    want = np.asarray(x @ w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
