//! Die-layout sweep (paper Fig. 11): all factor-pair layouts of 16 dies.
//!
//! ```bash
//! cargo run --release --example layout_sweep
//! ```

fn main() {
    println!("{}", hecaton::report::run("fig11").expect("fig11 report"));
}
