//! Weak-scaling study (paper §V-B / Fig. 9): scale h by k and dies by k²,
//! watch Hecaton's per-layer-per-token latency stay flat while the
//! baselines blow up.
//!
//! ```bash
//! cargo run --release --example weak_scaling
//! ```

fn main() {
    println!("{}", hecaton::report::run("weak").expect("weak-scaling report"));
    println!("{}", hecaton::report::run("fig9").expect("fig9 report"));
}
