//! Scenario-API quickstart: the whole library through the prelude, in a
//! handful of lines — one package, one hybrid cluster, one grid.
//!
//! ```bash
//! cargo run --release --example scenario_run
//! ```

use hecaton::prelude::*;

fn main() -> hecaton::Result<()> {
    // One package: Llama2-70B on the paper's 256-die testbed.
    let single = Scenario::builder(model_preset("llama2-70b").expect("preset"))
        .dies(256)
        .method(Method::Hecaton)
        .build()?;
    let eval = evaluate(&single)?;
    println!(
        "llama2-70b @ 256 dies: {} per batch, {:.0} tokens/s, feasible: {}",
        eval.latency(),
        eval.tokens_per_sec(),
        eval.feasible()
    );

    // A hybrid cluster: same API, one extra builder call.
    let cluster = Scenario::builder(model_preset("tinyllama-1.1b").expect("preset"))
        .dies(16)
        .cluster(4, 2, 2)
        .engine(EngineKind::Event)
        .build()?;
    let eval = evaluate(&cluster)?;
    let detail = eval.cluster().expect("cluster scenarios carry cluster detail");
    println!(
        "tinyllama @ 4 packages (dp=2 x pp=2): {} per batch ({} bubble, {} all-reduce)",
        eval.latency(),
        detail.bubble,
        detail.grad_allreduce
    );

    // Grids are scenarios too: all four TP methods through one plan cache.
    let grid = ScenarioGrid {
        models: vec![model_preset("tinyllama-1.1b").expect("preset")],
        meshes: vec![(4, 4)],
        packages: vec![PackageKind::Standard],
        drams: vec![DramKind::Ddr5_6400],
        methods: Method::all().to_vec(),
        engines: vec![EngineKind::Analytic],
        ..Default::default()
    };
    let (points, _skipped) = grid.points()?;
    let evals = run_all(&points)?;
    println!("method sweep (4x4 mesh):");
    for (s, e) in points.iter().zip(&evals) {
        println!("  {:<11} {}", s.method.name(), e.latency());
    }
    Ok(())
}
