//! DRAM-generation sweep (paper Fig. 10): DDR4 / DDR5 / HBM2.
//!
//! ```bash
//! cargo run --release --example dram_sweep
//! ```

fn main() {
    println!("{}", hecaton::report::run("fig10").expect("fig10 report"));
}
