//! Quickstart: simulate Llama2-70B training on a 256-die Hecaton package
//! and compare all four distributed methods.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hecaton::config::presets::model_preset;
use hecaton::config::{DramKind, HardwareConfig, PackageKind};
use hecaton::nop::analytic::Method;
use hecaton::sim::system::simulate;
use hecaton::table_row;
use hecaton::util::table::Table;

fn main() {
    let model = model_preset("llama2-70b").expect("preset");
    let hw = HardwareConfig::square(256, PackageKind::Standard, DramKind::Ddr5_6400);
    println!(
        "Simulating one {}-batch of {} on a 16x16 {} package ({} aggregate)…\n",
        model.batch,
        model.name,
        hw.package.name(),
        hecaton::util::fmt::flops(hw.peak_flops()),
    );

    let mut t = Table::new(&["method", "latency", "speedup", "energy", "NoP share", "SRAM"])
        .label_first();
    let hec = simulate(&model, &hw, Method::Hecaton);
    for m in Method::all() {
        let r = if m == Method::Hecaton {
            hec.clone()
        } else {
            simulate(&model, &hw, m)
        };
        t.row(table_row![
            r.method.name(),
            r.latency,
            format!("{:.2}x", r.latency / hec.latency),
            r.energy_total,
            format!(
                "{:.0}%",
                100.0 * (r.breakdown.nop_transmission + r.breakdown.nop_link).raw()
                    / r.latency.raw()
            ),
            if r.feasible() { "ok" } else { "overflow*" }
        ]);
    }
    println!("{}", t.render());
    println!("(*) method requires more than the 8 MB per-die SRAM buffers — paper Fig. 8 asterisks.");
}
