//! **End-to-end validation driver** (EXPERIMENTS.md E9): functional
//! distributed training with real numerics through the full stack —
//! Pallas-kernel artifacts (L1) lowered from the JAX model (L2), executed
//! by PJRT from the rust coordinator (L3) running Algorithm 1 on a 2×2
//! die mesh with ring all-gather / reduce-scatter collectives.
//!
//! Trains the `tiny` transformer for a few hundred steps on a synthetic
//! next-token corpus and logs the loss curve, then (with `--full`) runs a
//! shorter demonstration on the ~100M-parameter `e2e-100m` config.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e [-- --full]
//! ```

use hecaton::coordinator::{coord_model, Coordinator, MeshCfg};
use hecaton::train::data::Corpus;
use hecaton::train::{train, TrainCfg};

fn run(model_name: &str, rows: usize, cols: usize, tokens: usize, steps: usize, lr: f32) {
    let model = coord_model(model_name).expect("functional preset");
    println!(
        "=== {model_name}: {rows}x{cols} mesh, {} layers, h={}, {} tokens/mini-batch ===",
        model.layers, model.hidden, tokens
    );
    let mut corpus = Corpus::next_token(model.vocab, model.seq_len, 2024);
    let cfg = MeshCfg::new(model, rows, cols, tokens);
    let mut coord = Coordinator::new(cfg, 42).expect("mesh spawns");
    let t0 = std::time::Instant::now();
    let logs = train(
        &mut coord,
        &mut corpus,
        TrainCfg {
            steps,
            lr,
            seed: 7,
        },
    )
    .expect("training runs");
    let wall = t0.elapsed();

    println!("step,loss,wall_ms");
    for l in &logs {
        println!("{},{:.4},{}", l.step, l.loss, l.wall.as_millis());
    }
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    println!(
        "loss {first:.4} -> {last:.4} over {} steps ({:.1}s wall, {:.0} ms/step)",
        logs.len(),
        wall.as_secs_f64(),
        wall.as_millis() as f64 / logs.len() as f64
    );
    let die_stats = coord.die_stats().expect("stats");
    let execs: u64 = die_stats.iter().map(|s| s.executions).sum();
    let exec_time: f64 = die_stats.iter().map(|s| s.exec_time.as_secs_f64()).sum();
    println!(
        "die-side PJRT executions: {execs} ({:.2}s total across dies); leader: {} execs",
        exec_time,
        coord.leader_stats().executions
    );
    assert!(last < first, "training must reduce the loss");
    coord.shutdown().expect("clean shutdown");
    println!();
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    // Headline run: a few hundred steps on the tiny model.
    run("tiny", 2, 2, 64, 200, 0.5);
    if full {
        // ~100M-parameter config (12 layers, h=768): fewer steps — each
        // step is a full batch of 8×256 tokens through 4 dies.
        run("e2e-100m", 2, 2, 256, 30, 0.2);
    } else {
        println!("(run with --full for the ~100M-parameter e2e-100m config)");
    }
}
